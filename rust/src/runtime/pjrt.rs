//! PJRT-backed runtime: loads `artifacts/*.hlo.txt`, compiles once on
//! the PJRT CPU client, and serves train/eval/init execution to any
//! number of worker threads.
//!
//! Threading: the `xla` crate's `PjRtClient` wraps an `Rc` (not Send),
//! so a dedicated **service thread** owns the client + executables;
//! worker threads talk to it through a channel. XLA's CPU backend
//! already parallelizes inside a single execution, so one service
//! thread keeps the machine busy; a pool can be layered on top by
//! creating several `PjrtRuntime`s (each compiles its own copy).

use super::{EvalOut, ModelRuntime, StepOut};
use crate::data::Batch;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

enum Req {
    Init {
        seed: u32,
        reply: Sender<Result<Vec<f32>>>,
    },
    Train {
        params: Vec<f32>,
        global: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        lr: f32,
        mu: f32,
        reply: Sender<Result<StepOut>>,
    },
    Eval {
        params: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        reply: Sender<Result<(f32, f32)>>,
    },
    Shutdown,
}

/// Handle to the service thread. Cheap to clone; all clones share the
/// same compiled executables.
#[derive(Clone)]
pub struct PjrtRuntime {
    tx: Sender<Req>,
    info: super::ModelInfo,
    // keep the service thread's panic observable
    _joiner: Arc<JoinOnDrop>,
}

// The Sender is Send; the handle is shared across worker threads.
// (Mutex only to satisfy older mpsc Sender !Sync — std's Sender is
// Send+!Sync until 1.72; current std Sender is Sync, but stay safe.)
struct JoinOnDrop {
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    tx: Sender<Req>,
}

impl Drop for JoinOnDrop {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl PjrtRuntime {
    /// Load + compile the three artifacts for `model` from `dir`.
    pub fn load(dir: &str, model: &str) -> Result<PjrtRuntime> {
        let manifest = super::Manifest::load(dir)?;
        let info = manifest.model(model)?.clone();
        Self::from_info(&manifest.dir, info)
    }

    pub fn from_info(dir: &Path, info: super::ModelInfo) -> Result<PjrtRuntime> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let dir = dir.to_path_buf();
        let info_thread = info.clone();
        let handle = std::thread::Builder::new()
            .name(format!("pjrt-{}", info.name))
            .spawn(move || {
                let svc = match Service::new(&dir, &info_thread) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                svc.run(rx);
            })
            .context("spawning pjrt service thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt service thread died during startup"))??;
        Ok(PjrtRuntime {
            tx: tx.clone(),
            info,
            _joiner: Arc::new(JoinOnDrop {
                handle: Mutex::new(Some(handle)),
                tx,
            }),
        })
    }

    pub fn info(&self) -> &super::ModelInfo {
        &self.info
    }
}

/// Owns the PJRT client; runs on the service thread.
struct Service {
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    info: super::ModelInfo,
}

impl Service {
    fn new(dir: &Path, info: &super::ModelInfo) -> Result<Service> {
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        log::info!(
            "pjrt[{}]: platform={} compiling artifacts…",
            info.name,
            client.platform_name()
        );
        let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = info.hlo_path(dir, kind);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap_xla)
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            log::info!(
                "pjrt[{}]: compiled {kind} in {:.1}s",
                info.name,
                t0.elapsed().as_secs_f64()
            );
            Ok(exe)
        };
        Ok(Service {
            init: compile("init")?,
            train: compile("train")?,
            eval: compile("eval")?,
            info: info.clone(),
        })
    }

    fn run(self, rx: std::sync::mpsc::Receiver<Req>) {
        while let Ok(req) = rx.recv() {
            match req {
                Req::Init { seed, reply } => {
                    let _ = reply.send(self.do_init(seed));
                }
                Req::Train {
                    params,
                    global,
                    x,
                    y,
                    lr,
                    mu,
                    reply,
                } => {
                    let _ = reply.send(self.do_train(&params, &global, &x, &y, lr, mu));
                }
                Req::Eval {
                    params,
                    x,
                    y,
                    reply,
                } => {
                    let _ = reply.send(self.do_eval(&params, &x, &y));
                }
                Req::Shutdown => break,
            }
        }
    }

    fn x_literal(&self, x: &[f32], batch: usize) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![batch as i64];
        dims.extend(self.info.x_shape.iter().map(|&d| d as i64));
        let lit = if self.info.x_dtype == "i32" {
            let ints: Vec<i32> = x.iter().map(|&v| v as i32).collect();
            xla::Literal::vec1(&ints)
        } else {
            xla::Literal::vec1(x)
        };
        lit.reshape(&dims).map_err(wrap_xla)
    }

    fn y_literal(&self, y: &[i32], batch: usize) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![batch as i64];
        dims.extend(self.info.y_shape.iter().map(|&d| d as i64));
        xla::Literal::vec1(y).reshape(&dims).map_err(wrap_xla)
    }

    fn do_init(&self, seed: u32) -> Result<Vec<f32>> {
        let seed_lit = xla::Literal::scalar(seed);
        let out = self.init.execute::<xla::Literal>(&[seed_lit]).map_err(wrap_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(wrap_xla)?;
        let params = lit.to_tuple1().map_err(wrap_xla)?;
        let v = params.to_vec::<f32>().map_err(wrap_xla)?;
        if v.len() != self.info.n_params {
            bail!("init returned {} params, want {}", v.len(), self.info.n_params);
        }
        Ok(v)
    }

    fn do_train(
        &self,
        params: &[f32],
        global: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let b = self.info.train_batch;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(global),
            self.x_literal(x, b)?,
            self.y_literal(y, b)?,
            xla::Literal::scalar(lr),
            xla::Literal::scalar(mu),
        ];
        let out = self.train.execute::<xla::Literal>(&args).map_err(wrap_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(wrap_xla)?;
        let (p, loss, correct) = lit.to_tuple3().map_err(wrap_xla)?;
        Ok(StepOut {
            params: p.to_vec::<f32>().map_err(wrap_xla)?,
            loss: loss.get_first_element::<f32>().map_err(wrap_xla)?,
            correct: correct.get_first_element::<f32>().map_err(wrap_xla)?,
        })
    }

    fn do_eval(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.info.eval_batch;
        let args = [
            xla::Literal::vec1(params),
            self.x_literal(x, b)?,
            self.y_literal(y, b)?,
        ];
        let out = self.eval.execute::<xla::Literal>(&args).map_err(wrap_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(wrap_xla)?;
        let (loss_sum, correct) = lit.to_tuple2().map_err(wrap_xla)?;
        Ok((
            loss_sum.get_first_element::<f32>().map_err(wrap_xla)?,
            correct.get_first_element::<f32>().map_err(wrap_xla)?,
        ))
    }
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

impl ModelRuntime for PjrtRuntime {
    fn n_params(&self) -> usize {
        self.info.n_params
    }

    fn train_batch(&self) -> usize {
        self.info.train_batch
    }

    fn eval_batch(&self) -> usize {
        self.info.eval_batch
    }

    fn samples_per_example(&self) -> usize {
        self.info.samples_per_example
    }

    fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Init { seed, reply })
            .map_err(|_| anyhow!("pjrt service gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt service gone"))?
    }

    fn train_step(
        &self,
        params: &[f32],
        global: &[f32],
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        if batch.n != self.info.train_batch {
            bail!(
                "train batch {} != artifact batch {}",
                batch.n,
                self.info.train_batch
            );
        }
        let (reply, rx) = channel();
        self.tx
            .send(Req::Train {
                params: params.to_vec(),
                global: global.to_vec(),
                x: batch.x.clone(),
                y: batch.y.clone(),
                lr,
                mu,
                reply,
            })
            .map_err(|_| anyhow!("pjrt service gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt service gone"))?
    }

    fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        if batch.n != self.info.eval_batch {
            bail!(
                "eval batch {} != artifact batch {}",
                batch.n,
                self.info.eval_batch
            );
        }
        let (reply, rx) = channel();
        self.tx
            .send(Req::Eval {
                params: params.to_vec(),
                x: batch.x.clone(),
                y: batch.y.clone(),
                reply,
            })
            .map_err(|_| anyhow!("pjrt service gone"))?;
        let (loss_sum, correct) = rx.recv().map_err(|_| anyhow!("pjrt service gone"))??;
        Ok(EvalOut {
            loss_sum,
            correct,
            n: (batch.n * self.info.samples_per_example) as u64,
        })
    }
}

// Integration tests live in rust/tests/pjrt_integration.rs (they need
// built artifacts); unit coverage here is limited to handle plumbing.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let err = PjrtRuntime::load("/nonexistent-dir", "medmnist_mlp")
            .err()
            .expect("should fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
