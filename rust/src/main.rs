//! `fedhpc` — CLI launcher for the federated learning framework.
//!
//! Subcommands:
//!   train        run a federated training experiment (preset or JSON config)
//!   experiment   regenerate a paper table/figure (see DESIGN.md §4)
//!   serve        start a TCP orchestrator (multi-process deployment)
//!   worker       start a TCP worker and connect to an orchestrator
//!   sim          virtual-time run (timing studies)
//!   list         list models, presets, SKUs and experiments

use anyhow::{Context, Result};
use fedhpc::client::{Worker, WorkerOptions};
use fedhpc::cluster::{Cluster, SiteMap};
use fedhpc::config::{self, ExperimentConfig, Preset};
use fedhpc::data::FederatedDataset;
use fedhpc::experiments;
use fedhpc::faults::FaultInjector;
use fedhpc::network::tcp::{TcpClient, TcpServer};
use fedhpc::network::{ClientProfile, LinkShaper, Msg, TrafficLog};
use fedhpc::orchestrator::{Aggregator, EvalHarness, NoHooks, Orchestrator};
use fedhpc::runtime::{Manifest, MockRuntime, ModelRuntime, PjrtRuntime};
use fedhpc::telemetry::{ControlPlane, TelemetryServer};
use fedhpc::util::argparse::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    fedhpc::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let (cmd, rest) = argv.split_first().unwrap();
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "experiment" => cmd_experiment(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "sim" => cmd_sim(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "fedhpc {} — federated learning for heterogeneous HPC + cloud

usage: fedhpc <command> [options]

commands:
  train       run federated training (--preset quickstart|paper, or --config file.json)
  experiment  regenerate a paper table/figure (--id table2|table3|table4|straggler|ablation-*|all)
  serve       TCP orchestrator for multi-process deployment
  worker      TCP worker process (connect to a serve instance)
  sim         virtual-time timing run
  list        models, presets, SKUs, experiments",
        fedhpc::VERSION
    );
}

fn load_config(p: &fedhpc::util::argparse::Parsed) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = p.get("config") {
        config::from_json_file(path)?
    } else {
        let preset = p.get("preset").unwrap_or("quickstart");
        Preset::parse(preset)
            .with_context(|| format!("unknown preset '{preset}'"))?
            .build()
    };
    if let Some(r) = p.get("rounds") {
        cfg.train.rounds = r.parse().context("--rounds")?;
    }
    if let Some(m) = p.get("model") {
        cfg.data.dataset = m.to_string();
    }
    if let Some(s) = p.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if p.has("mock") {
        cfg.mock_runtime = true;
    }
    if let Some(a) = p.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    // strategy overrides by registry name (see `fedhpc list`)
    if let Some(a) = p.get("aggregation") {
        cfg.aggregation = config::Aggregation::parse(a).context("--aggregation")?;
    }
    if let Some(o) = p.get("server-opt") {
        cfg.server_opt = config::ServerOptKind::parse(o).context("--server-opt")?;
    }
    if let Some(m) = p.get("round-mode") {
        cfg.round_mode = config::RoundMode::parse(m).context("--round-mode")?;
    }
    if let Some(pl) = p.get("planner") {
        cfg.selection.planner = Some(config::PlannerKind::parse(pl).context("--planner")?);
    }
    if let Some(g) = p.get("grouping") {
        cfg.hierarchy.grouping = config::GroupingPolicy::parse(g).context("--grouping")?;
    }
    if let Some(addr) = p.get("telemetry-addr") {
        cfg.telemetry.addr = Some(addr.to_string());
    }
    if let Some(t) = p.get("ingest-threads") {
        cfg.ingest_threads = t.parse().context("--ingest-threads")?;
    }
    if let Some(m) = p.get("max-connections") {
        cfg.transport.max_connections = m.parse().context("--max-connections")?;
    }
    if let Some(c) = p.get("transport-compression") {
        cfg.transport.compression = match c {
            "on" => true,
            "off" => false,
            other => anyhow::bail!("--transport-compression must be 'on' or 'off', got '{other}'"),
        };
    }
    config::validate(&cfg)?;
    Ok(cfg)
}

/// If the config enables telemetry, bind the operations endpoint and
/// return it with its control plane; `None` means disabled.
fn start_telemetry(
    cfg: &ExperimentConfig,
) -> Result<Option<(TelemetryServer, Arc<ControlPlane>)>> {
    let Some(addr) = &cfg.telemetry.addr else {
        return Ok(None);
    };
    let control = Arc::new(ControlPlane::new());
    let server = TelemetryServer::bind(addr, fedhpc::telemetry::global().clone(), control.clone())
        .with_context(|| format!("binding telemetry endpoint {addr}"))?;
    println!("telemetry listening on http://{}", server.local_addr());
    Ok(Some((server, control)))
}

fn train_args() -> Args {
    Args::new()
        .opt("preset", Some("quickstart"), "preset: quickstart | paper")
        .opt("config", None, "JSON config file (overrides preset)")
        .opt("rounds", None, "override training rounds")
        .opt("model", None, "override dataset/model")
        .opt("seed", None, "override experiment seed")
        .opt("artifacts", None, "artifacts directory")
        .opt(
            "aggregation",
            None,
            "aggregation strategy: fedavg | fedprox[:mu] | weighted[:scheme] | \
             trimmed_mean[:frac] | coordinate_median",
        )
        .opt(
            "server-opt",
            None,
            "server optimizer: sgd | fedavgm[:beta] | fedadam[:lr]",
        )
        .opt(
            "round-mode",
            None,
            "round engine: sync | async_fedbuff[:buffer_k[:alpha[:max_staleness]]]",
        )
        .opt(
            "planner",
            None,
            "cohort planner: random | adaptive[:explore[:exclude]] | tiered[:n] | \
             deadline[:ms]",
        )
        .opt(
            "grouping",
            None,
            "aggregation tree grouping: flat | site[:n] | zone",
        )
        .opt("out", Some("results"), "output directory for reports")
        .opt(
            "telemetry-addr",
            None,
            "bind live /metrics + control endpoint (e.g. 127.0.0.1:9469)",
        )
        .opt(
            "ingest-threads",
            None,
            "shard-worker threads for parallel server ingest: 0 = auto, 1 = serial",
        )
        .opt(
            "max-connections",
            None,
            "TCP connection cap for the serve reactor (default 10240)",
        )
        .opt(
            "transport-compression",
            None,
            "transparent TCP frame compression: on | off (default on)",
        )
        .flag("mock", "use the pure-Rust mock runtime")
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let p = train_args().parse(rest)?;
    let cfg = load_config(&p)?;
    println!(
        "training '{}': {} on {} nodes, {} rounds",
        cfg.name,
        cfg.data.dataset,
        cfg.cluster.total_nodes(),
        cfg.train.rounds
    );
    let telemetry = start_telemetry(&cfg)?;
    let report = experiments::run_real_with_control(
        &cfg,
        &mut NoHooks,
        telemetry.as_ref().map(|(_, cp)| cp.clone()),
    )?;
    if let Some((server, control)) = telemetry {
        control.set_status("state=done".to_string());
        server.shutdown();
    }
    report.save(p.get("out").unwrap_or("results"))?;
    println!(
        "done: final acc {} | best {} | total {:.1}s | up {} down {}",
        report
            .final_accuracy()
            .map_or("-".into(), |a| format!("{:.3}", a)),
        report
            .best_accuracy()
            .map_or("-".into(), |a| format!("{:.3}", a)),
        report.total_duration_s(),
        fedhpc::util::human_bytes(report.total_bytes().1),
        fedhpc::util::human_bytes(report.total_bytes().0),
    );
    Ok(())
}

fn cmd_experiment(rest: &[String]) -> Result<()> {
    let p = Args::new()
        .opt("id", None, "experiment id (or 'all')")
        .opt("out", Some("results"), "output directory")
        .flag("quick", "smoke-test scale")
        .parse(rest)?;
    let id = p.req("id")?;
    experiments::run(id, p.has("quick"), p.get("out").unwrap_or("results"))
}

fn cmd_sim(rest: &[String]) -> Result<()> {
    let p = train_args().parse(rest)?;
    let cfg = load_config(&p)?;
    // sim is virtual-time: the endpoint is exposition-only (control
    // verbs are accepted but there is no round loop to drain them)
    let telemetry = start_telemetry(&cfg)?;
    if let Some((_, cp)) = &telemetry {
        cp.set_status("state=sim".to_string());
        cp.mark_ready();
    }
    let sim = experiments::run_sim(&cfg, &experiments::SimTiming::default(), false)?;
    if let Some((server, control)) = telemetry {
        control.set_status("state=done".to_string());
        server.shutdown();
    }
    println!(
        "virtual time: {:.1}s over {} rounds ({:.2}s/round)",
        sim.total_time_s,
        sim.report.rounds.len(),
        sim.total_time_s / sim.report.rounds.len().max(1) as f64
    );
    sim.report.save(p.get("out").unwrap_or("results"))?;
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let p = Args::new()
        .opt("bind", Some("127.0.0.1:7070"), "listen address")
        .opt(
            "role",
            Some("server"),
            "node role in the aggregation tree: server (root) | aggregator (mid-tier)",
        )
        .opt(
            "upstream",
            None,
            "root orchestrator address (required with --role aggregator)",
        )
        .opt(
            "site",
            None,
            "site index this aggregator serves (required with --role aggregator)",
        )
        .opt("preset", Some("quickstart"), "preset: quickstart | paper")
        .opt("config", None, "JSON config file")
        .opt("rounds", None, "override training rounds")
        .opt("model", None, "override dataset/model")
        .opt("seed", None, "override seed")
        .opt("artifacts", None, "artifacts directory")
        .opt("aggregation", None, "aggregation strategy by registry name")
        .opt("server-opt", None, "server optimizer by registry name")
        .opt("round-mode", None, "round engine by registry name")
        .opt("planner", None, "cohort planner by registry name")
        .opt(
            "grouping",
            None,
            "aggregation tree grouping: flat | site[:n] | zone",
        )
        .opt("out", Some("results"), "output directory")
        .opt("clients", None, "expected worker count (default: cluster size)")
        .opt(
            "telemetry-addr",
            None,
            "bind live /metrics + control endpoint (e.g. 127.0.0.1:9469)",
        )
        .opt(
            "ingest-threads",
            None,
            "shard-worker threads for parallel server ingest: 0 = auto, 1 = serial",
        )
        .opt(
            "max-connections",
            None,
            "TCP connection cap for the reactor (default 10240)",
        )
        .opt(
            "transport-compression",
            None,
            "transparent TCP frame compression: on | off (default on)",
        )
        .flag("mock", "use the mock runtime")
        .parse(rest)?;
    let mut cfg = load_config(&p)?;
    match p.get("role").unwrap_or("server") {
        "server" => {}
        "aggregator" => return serve_aggregator(&p, &cfg),
        other => anyhow::bail!("--role must be 'server' or 'aggregator', got '{other}'"),
    }
    // Root of a multi-process aggregator tree: the registered "clients"
    // are the site aggregators, one per site — the same transform the
    // in-process launcher applies (select every site, no partial-k,
    // doubled round budget so site rounds fit inside root rounds).
    let hier_sites = if cfg.hierarchy.enabled() {
        let n = SiteMap::build(&cfg.cluster, cfg.hierarchy.grouping)?.n_sites();
        cfg.selection.clients_per_round = n;
        cfg.straggler.partial_k = None;
        cfg.straggler.deadline_ms = cfg.straggler.deadline_ms.map(|d| d.saturating_mul(2));
        Some(n)
    } else {
        None
    };
    let expected = match p.get("clients") {
        Some(c) => c.parse().context("--clients")?,
        None => hier_sites.unwrap_or_else(|| cfg.cluster.total_nodes()),
    };
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind_with(p.get("bind").unwrap(), &cfg.transport, traffic.clone())?;
    println!(
        "orchestrator listening on {} (max {} connections, compression {}{})",
        server.local_addr,
        cfg.transport.max_connections,
        if cfg.transport.compression { "on" } else { "off" },
        match hier_sites {
            Some(n) => format!(", tree root over {n} sites"),
            None => String::new(),
        }
    );

    // centralized eval set + initial params; in tree mode the model
    // shapes must match what the workers (who shard over the full
    // cluster) derive, not the aggregator count
    let data_clients = if hier_sites.is_some() {
        cfg.cluster.total_nodes()
    } else {
        expected
    };
    let dataset = FederatedDataset::build(&cfg.data, data_clients, cfg.seed)?;
    let runtime: Box<dyn ModelRuntime> = if cfg.mock_runtime {
        Box::new(MockRuntime::new(dataset.eval.x_len, dataset.n_classes))
    } else {
        Box::new(PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)?)
    };
    let initial = runtime.init(cfg.seed as u32)?;
    let eval = EvalHarness {
        runtime,
        shard: dataset.eval.clone(),
    };
    let telemetry = start_telemetry(&cfg)?;
    let mut builder = Orchestrator::builder(cfg.clone())
        .transport(server)
        .traffic(traffic)
        .initial_params(initial)
        .eval(eval);
    if let Some((_, cp)) = &telemetry {
        cp.set_identity("server", None);
        builder = builder.control(cp.clone());
    }
    let mut orch = builder.build()?;
    let report = orch.run(Some((expected, Duration::from_secs(120))), &mut NoHooks)?;
    if let Some((tsrv, control)) = telemetry {
        control.set_status("state=done".to_string());
        tsrv.shutdown();
    }
    report.save(p.get("out").unwrap_or("results"))?;
    println!(
        "done: final acc {}",
        report
            .final_accuracy()
            .map_or("-".into(), |a| format!("{:.3}", a))
    );
    Ok(())
}

/// `serve --role aggregator`: a mid-tier node that serves one site's
/// workers over its own TCP listener and reports the folded site delta
/// upstream to the root, speaking the ordinary client protocol.
fn serve_aggregator(p: &fedhpc::util::argparse::Parsed, cfg: &ExperimentConfig) -> Result<()> {
    let upstream_addr = p
        .get("upstream")
        .context("--role aggregator requires --upstream <addr>")?;
    let site: usize = p
        .get("site")
        .context("--role aggregator requires --site <idx>")?
        .parse()
        .context("--site")?;
    let map = SiteMap::build(&cfg.cluster, cfg.hierarchy.grouping)?;
    if site >= map.n_sites() {
        anyhow::bail!(
            "--site {site} out of range: the {} grouping yields {} sites",
            cfg.hierarchy.grouping.name(),
            map.n_sites()
        );
    }
    let rep = map
        .representative(site)
        .with_context(|| format!("site {site} has no members"))?;
    let expected = match p.get("clients") {
        Some(c) => c.parse().context("--clients")?,
        None => map.members(site).len(),
    };

    // The aggregator never trains, but it re-encodes the folded site
    // delta, so it needs the model's parameter count — derived the same
    // way the root derives it (same seed ⇒ same shapes).
    let dataset = FederatedDataset::build(&cfg.data, cfg.cluster.total_nodes(), cfg.seed)?;
    let runtime: Box<dyn ModelRuntime> = if cfg.mock_runtime {
        Box::new(MockRuntime::new(dataset.eval.x_len, dataset.n_classes))
    } else {
        Box::new(PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)?)
    };
    let n_params = runtime.init(cfg.seed as u32)?.len();

    let traffic = Arc::new(TrafficLog::new());
    let downstream = TcpServer::bind_with(p.get("bind").unwrap(), &cfg.transport, traffic)?;
    println!(
        "site {site} aggregator listening on {} ({expected} workers expected)",
        downstream.local_addr
    );

    // Connect upstream as the site's representative. The profile here
    // is a placeholder for the connect handshake; `Aggregator::run`
    // re-registers with the true site-aggregate profile once the
    // members have joined.
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let node = cluster
        .node(rep)
        .with_context(|| format!("representative {rep} exceeds cluster size"))?;
    let upstream = TcpClient::connect_with(
        upstream_addr,
        &Msg::Register {
            client: rep,
            profile: ClientProfile {
                speed_factor: 1.0,
                mem_gb: 1.0,
                link_bw: 1.0e9,
                n_samples: 1,
                bench_step_ms: 1.0,
            },
        },
        LinkShaper::from_class(node.link()),
        Arc::new(TrafficLog::new()),
        cfg.transport.compression,
    )?;
    println!("site {site} aggregator connected upstream to {upstream_addr} as client {rep}");

    let telemetry = start_telemetry(cfg)?;
    if let Some((_, cp)) = &telemetry {
        cp.set_identity("aggregator", Some(upstream_addr));
        cp.set_status(format!("state=site-aggregator site={site}"));
        cp.mark_ready();
    }
    let mut agg = Aggregator::new(cfg.clone(), site, n_params, downstream, upstream);
    let rounds = agg.run(expected, Duration::from_secs(120))?;
    if let Some((tsrv, control)) = telemetry {
        control.set_status("state=done".to_string());
        tsrv.shutdown();
    }
    println!("site {site} aggregator done after {rounds} rounds");
    Ok(())
}

fn cmd_worker(rest: &[String]) -> Result<()> {
    let p = Args::new()
        .opt("connect", Some("127.0.0.1:7070"), "orchestrator address")
        .opt("id", None, "client id (u32)")
        .opt("preset", Some("quickstart"), "preset (must match server)")
        .opt("config", None, "JSON config file (must match server)")
        .opt("model", None, "override dataset/model")
        .opt("seed", None, "override seed (must match server)")
        .opt("artifacts", None, "artifacts directory")
        .opt("clients", None, "total worker count (must match server)")
        .opt(
            "transport-compression",
            None,
            "transparent TCP frame compression: on | off (default on)",
        )
        .flag("mock", "use the mock runtime")
        .parse(rest)?;
    let cfg = load_config(&p)?;
    let id: u32 = p.req("id")?.parse().context("--id")?;
    let n_clients = match p.get("clients") {
        Some(c) => c.parse().context("--clients")?,
        None => cfg.cluster.total_nodes(),
    };
    // the same seed ⇒ same cluster + same partition as the server
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let dataset = FederatedDataset::build(&cfg.data, n_clients, cfg.seed)?;
    let node = cluster
        .node(id)
        .with_context(|| format!("client id {id} exceeds cluster size {}", cluster.len()))?
        .clone();
    let shard = dataset.clients[id as usize].clone();
    let runtime: Box<dyn ModelRuntime> = if cfg.mock_runtime {
        Box::new(MockRuntime::new(shard.x_len, dataset.n_classes))
    } else {
        Box::new(PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)?)
    };
    let traffic = Arc::new(TrafficLog::new());
    let profile = fedhpc::client::profile_runtime(runtime.as_ref(), &node, &shard, 0)?;
    let transport = TcpClient::connect_with(
        p.get("connect").unwrap(),
        &Msg::Register {
            client: id,
            profile,
        },
        LinkShaper::from_class(node.link()),
        traffic,
        cfg.transport.compression,
    )?;
    println!("worker {id} connected ({})", node.sku.name);
    let worker = Worker::new(
        transport,
        runtime,
        node,
        shard,
        FaultInjector::new(cfg.faults, cfg.seed),
        WorkerOptions {
            seed: cfg.seed ^ id as u64,
            ..Default::default()
        },
    );
    // Register is sent twice (once by connect, once by run) — the
    // orchestrator treats re-registration as a profile refresh.
    let rounds = worker.run()?;
    println!("worker {id} done after {rounds} rounds");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("presets: quickstart, paper");
    println!(
        "\naggregation strategies: {}",
        fedhpc::orchestrator::strategy::registry::strategy_names().join(", ")
    );
    println!(
        "server optimizers: {}",
        fedhpc::orchestrator::strategy::registry::server_opt_names().join(", ")
    );
    println!(
        "cohort planners: {} (adaptive[:explore[:exclude]], tiered[:n], deadline[:ms])",
        fedhpc::orchestrator::planner::planner_names().join(", ")
    );
    println!(
        "weight schemes (weighted[:scheme]): {}",
        fedhpc::config::WeightScheme::KINDS.join(", ")
    );
    println!(
        "round modes: {} (async: async_fedbuff[:buffer_k[:alpha[:max_staleness]]], \
         staleness fns: {})",
        fedhpc::config::RoundMode::KINDS.join(", "),
        fedhpc::config::StalenessFn::KINDS.join(", ")
    );
    println!(
        "hierarchy groupings: {} (site[:n]; serve --role aggregator --site <idx>)",
        fedhpc::config::GroupingPolicy::KINDS.join(", ")
    );
    println!("\nSKUs:");
    for sku in fedhpc::cluster::catalog() {
        println!(
            "  {:<18} {:?}/{:?} speed={:.3} link={:?} preempt={}/h",
            sku.name, sku.domain, sku.accel, sku.speed_factor, sku.link, sku.preempt_per_hour
        );
    }
    println!("\nexperiments:");
    for (id, desc) in experiments::EXPERIMENTS {
        println!("  {id:<22} {desc}");
    }
    match Manifest::load("artifacts") {
        Ok(m) => {
            println!("\nmodels (artifacts/):");
            for (name, info) in &m.models {
                println!(
                    "  {:<14} P={:<9} train_batch={} impl={}",
                    name, info.n_params, info.train_batch, info.kernel_impl
                );
            }
        }
        Err(_) => println!("\nmodels: artifacts/ not built (run `make artifacts`)"),
    }
    Ok(())
}
