//! Discrete-event virtual time (S15 in DESIGN.md).
//!
//! Timing experiments (Table 3 scalability, ablations E5/E7) must not
//! depend on this machine's wall clock: a round's duration is *derived*
//! from node speed factors, payload sizes and link profiles, then the
//! orchestrator's deadline / partial-k logic plays out against virtual
//! time. [`EventQueue`] is a classic min-heap discrete-event core;
//! [`VirtualClock`] is the shared notion of "now".
//!
//! # Determinism contract
//!
//! Both types are fully deterministic: [`EventQueue`] breaks equal
//! timestamps by insertion order (FIFO), so two runs that push the
//! same events in the same order pop them in the same order — the
//! foundation of the sim runner's "same seed ⇒ same commit sequence"
//! guarantee (see `experiments::simrunner`). [`VirtualClock::advance_to`]
//! returns an error (never panics) on backwards time: in buffered-async
//! mode event times are derived from wire-carried client state, so a
//! regression must surface as a recoverable error, not a crash.

use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Monotonic virtual clock (seconds).
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Advance to `t_s`. Backwards time (beyond a small epsilon) is an
    /// error — reachable from the wire in async mode, so it must not
    /// panic; callers decide whether to drop the event or abort.
    pub fn advance_to(&mut self, t_s: f64) -> Result<()> {
        if t_s.is_nan() || t_s < self.now_s - 1e-12 {
            bail!(
                "virtual time went backwards: {} -> {t_s}",
                self.now_s
            );
        }
        self.now_s = self.now_s.max(t_s);
        Ok(())
    }
}

/// Min-heap of timestamped events.
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Event<T>>>,
    seq: u64,
}

struct Event<T> {
    at_s: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at_s == other.at_s && self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}
impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at_s
            .total_cmp(&other.at_s)
            .then(self.seq.cmp(&other.seq))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, at_s: f64, payload: T) {
        self.seq += 1;
        self.heap.push(Reverse(Event {
            at_s,
            seq: self.seq,
            payload,
        }));
    }

    /// Pop the earliest event: (time, payload).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|Reverse(e)| (e.at_s, e.payload))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.at_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_monotonic() {
        let mut c = VirtualClock::new();
        c.advance_to(5.0).unwrap();
        c.advance_to(5.0).unwrap();
        c.advance_to(7.5).unwrap();
        assert_eq!(c.now_s(), 7.5);
    }

    /// Regression (ISSUE 4 satellite): backwards time used to panic;
    /// it is wire-reachable in async mode, so it must be an error the
    /// caller can handle — and must leave the clock untouched.
    #[test]
    fn clock_rejects_regression_as_error() {
        let mut c = VirtualClock::new();
        c.advance_to(5.0).unwrap();
        let err = c.advance_to(4.0).unwrap_err();
        assert!(format!("{err}").contains("backwards"), "{err}");
        assert_eq!(c.now_s(), 5.0, "failed advance must not move the clock");
        // NaN is also a rejected (non-monotonic) target
        assert!(c.advance_to(f64::NAN).is_err());
        assert_eq!(c.now_s(), 5.0);
        // within-epsilon jitter is tolerated and clamped forward
        c.advance_to(5.0 - 1e-13).unwrap();
        assert_eq!(c.now_s(), 5.0);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, "first");
        q.push(1.0, "second");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
    }

    /// FIFO at equal timestamps must hold for long runs of ties and
    /// survive interleaved pops — the property async replay leans on.
    #[test]
    fn long_tie_runs_stay_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.push(2.0, i);
        }
        // an earlier event pops first regardless of insertion position
        q.push(1.0, 999);
        assert_eq!(q.pop(), Some((1.0, 999)));
        for i in 0..50u32 {
            assert_eq!(q.pop(), Some((2.0, i)));
        }
        // new ties enqueue after the still-pending older ties
        q.push(2.0, 1000);
        for i in 50..100u32 {
            assert_eq!(q.pop(), Some((2.0, i)));
        }
        assert_eq!(q.pop(), Some((2.0, 1000)));
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, 10);
        q.push(1.0, 1);
        assert_eq!(q.pop(), Some((1.0, 1)));
        q.push(5.0, 5);
        assert_eq!(q.pop(), Some((5.0, 5)));
        assert_eq!(q.pop(), Some((10.0, 10)));
        assert!(q.is_empty());
    }
}
