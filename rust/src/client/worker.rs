//! Worker event loop: one federated client.
//!
//! Lifecycle: profile → Register → loop { RoundStart → (fault check) →
//! local training → compress → Update } until Shutdown. Heterogeneity
//! emulation: after real compute, the worker sleeps the *extra* time
//! its simulated SKU would have needed (capped, so CPU-class nodes
//! don't stall real runs for minutes); fault injection applies
//! dropouts / preemptions / straggles exactly where a deployment would
//! see them.

use super::profile::profile_runtime;
use super::trainer::train_local;
use crate::cluster::Node;
use crate::compress::{compress, decompress_owned, DecodedView, Encoded};
use crate::data::Shard;
use crate::faults::{FaultAction, FaultInjector};
use crate::network::{ClientTransport, Msg, UpdateStats};
use crate::runtime::ModelRuntime;
use crate::util::scratch::ScratchPool;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Worker tunables.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Emulate SKU speed by sleeping (speed_factor < 1 ⇒ extra wait).
    pub emulate_speed: bool,
    /// Cap on emulated slowdown factor (keeps real runs bounded).
    pub max_slowdown: f64,
    /// Benchmark steps for the registration profile.
    pub bench_steps: usize,
    pub seed: u64,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            emulate_speed: true,
            max_slowdown: 4.0,
            bench_steps: 0,
            seed: 0,
        }
    }
}

/// A federated worker bound to one node, one shard, one runtime.
pub struct Worker<T: ClientTransport> {
    transport: T,
    runtime: Box<dyn ModelRuntime>,
    node: Node,
    shard: Shard,
    injector: FaultInjector,
    opts: WorkerOptions,
    /// Recycles the per-round global-model decode buffer.
    scratch: ScratchPool,
}

impl<T: ClientTransport> Worker<T> {
    pub fn new(
        transport: T,
        runtime: Box<dyn ModelRuntime>,
        node: Node,
        shard: Shard,
        injector: FaultInjector,
        opts: WorkerOptions,
    ) -> Self {
        Worker {
            transport,
            runtime,
            node,
            shard,
            injector,
            opts,
            scratch: ScratchPool::new(),
        }
    }

    /// Decode the broadcast global model into a dense vector. Owned
    /// dense payloads (the normal broadcast) move straight out with no
    /// copy ([`decompress_owned`]); compressed broadcasts scatter into
    /// a pooled scratch buffer instead of a fresh `vec![0f32; P]` per
    /// round. Returns `true` when the buffer came from the pool — only
    /// those go back via `put` after training (pooling the moved-out
    /// message payloads would grow the pool by one dead buffer per
    /// round, since that path never takes from it).
    fn decode_global(&self, params: Encoded) -> Result<(Vec<f32>, bool)> {
        let n = self.runtime.n_params();
        match params {
            p @ (Encoded::Dense(_) | Encoded::PreEncoded(_)) => {
                Ok((decompress_owned(p, n)?, false))
            }
            enc => {
                let view = DecodedView::of(&enc, n)?;
                let mut buf = self.scratch.take(n);
                view.write_dense(&mut buf);
                Ok((buf, true))
            }
        }
    }

    /// Register with the orchestrator (sends the profiling benchmark).
    pub fn register(&self) -> Result<()> {
        let profile = profile_runtime(
            self.runtime.as_ref(),
            &self.node,
            &self.shard,
            self.opts.bench_steps,
        )?;
        self.transport.send(&Msg::Register {
            client: self.transport.id(),
            profile,
        })
    }

    /// Main loop; returns the number of rounds participated in.
    pub fn run(&self) -> Result<u64> {
        self.register()?;
        let mut rounds = 0u64;
        loop {
            let Some(msg) = self
                .transport
                .recv_timeout(Duration::from_millis(250))?
            else {
                continue;
            };
            match msg {
                Msg::RoundStart {
                    round,
                    model_version,
                    deadline_ms: _,
                    lr,
                    mu,
                    local_epochs,
                    params,
                    mask_seed,
                    compression,
                } => {
                    let id = self.transport.id();
                    let is_spot = self.node.sku.preempt_per_hour > 0.0;
                    let action = self.injector.action(round, id, is_spot);
                    if action == FaultAction::Dropout {
                        log::debug!("worker {id}: injected dropout in round {round}");
                        continue;
                    }
                    let (global, pooled) = self.decode_global(params)?;
                    let stop_frac = match action {
                        FaultAction::Preempt { progress } => progress,
                        _ => 1.0,
                    };
                    let t0 = Instant::now();
                    let outcome = train_local(
                        self.runtime.as_ref(),
                        &self.shard,
                        &global,
                        local_epochs as usize,
                        lr,
                        mu,
                        self.opts.seed ^ (((round as u64) << 20) | id as u64),
                        stop_frac,
                    )?;
                    let compute = t0.elapsed();
                    // training no longer needs the global model —
                    // recycle pool-owned buffers for the next decode
                    if pooled {
                        self.scratch.put(global);
                    }
                    self.emulate_heterogeneity(compute, &action);
                    if let FaultAction::Preempt { .. } = action {
                        log::debug!("worker {id}: preempted in round {round}");
                        continue; // compute wasted, nothing reported
                    }
                    let delta = compress(&outcome.delta, &compression, mask_seed);
                    // report which model this update is relative to —
                    // under buffered-async rounds the server may have
                    // committed newer versions while we trained, and it
                    // weights this update by that staleness
                    self.transport.send(&Msg::Update {
                        round,
                        client: id,
                        base_version: model_version,
                        delta,
                        stats: UpdateStats {
                            n_samples: outcome.n_samples,
                            train_loss: outcome.train_loss,
                            steps: outcome.steps,
                            compute_ms: compute.as_secs_f64() * 1e3,
                            update_var: outcome.update_var,
                        },
                    })?;
                    rounds += 1;
                }
                Msg::RoundEnd { .. } | Msg::RegisterAck { .. } | Msg::Abort { .. } => {}
                Msg::Shutdown => return Ok(rounds),
                other => log::debug!("worker: unexpected {}", other.name()),
            }
        }
    }

    /// Sleep out the difference between this node's simulated speed and
    /// real compute speed, plus any injected straggle.
    fn emulate_heterogeneity(&self, compute: Duration, action: &FaultAction) {
        let mut factor = 1.0f64;
        if self.opts.emulate_speed {
            factor *= (1.0 / self.node.speed_factor.max(1e-6)).clamp(1.0, self.opts.max_slowdown);
        }
        if let FaultAction::Straggle { factor: f } = action {
            factor *= f;
        }
        if factor > 1.0 {
            let extra = compute.mul_f64(factor - 1.0);
            // bounded so tests never hang on absurd configs
            std::thread::sleep(extra.min(Duration::from_secs(30)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::{ClusterConfig, CompressionConfig};
    use crate::network::inproc::InprocHub;
    use crate::network::{LinkShaper, ServerTransport, TrafficLog};
    use crate::runtime::MockRuntime;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn toy_shard(dim: usize, classes: usize, n: usize, seed: u64) -> Shard {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let cls = rng.below(classes);
            for j in 0..dim {
                x.push(if j % classes == cls { 1.5 } else { 0.0 });
            }
            y.push(cls as i32);
        }
        Shard {
            x,
            y,
            n,
            x_len: dim,
            y_len: 1,
        }
    }

    fn node_of(sku: &str) -> Node {
        Cluster::build(
            &ClusterConfig {
                nodes: vec![(sku.into(), 1)],
                cloud_backend: "inproc".into(),
                hpc_backend: "inproc".into(),
            },
            0,
        )
        .unwrap()
        .nodes[0]
            .clone()
    }

    fn one_node() -> Node {
        node_of("hpc-rtx6000")
    }

    #[test]
    fn worker_registers_trains_and_shuts_down() {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic);
        let endpoint = hub.add_client(0, LinkShaper::unshaped());
        let server = hub.server();
        let rt = MockRuntime::new(12, 3);
        let n_params = rt.n_params();
        let global = rt.init(0).unwrap();
        let worker = Worker::new(
            endpoint,
            Box::new(rt),
            one_node(),
            toy_shard(12, 3, 32, 1),
            FaultInjector::disabled(),
            WorkerOptions {
                emulate_speed: false,
                ..Default::default()
            },
        );
        let handle = std::thread::spawn(move || worker.run().unwrap());

        // orchestrator side, hand-rolled for the test
        let (from, msg) = server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        assert_eq!(from, 0);
        assert!(matches!(msg, Msg::Register { .. }));
        server
            .send_to(
                0,
                &Msg::RoundStart {
                    round: 0,
                    model_version: 0,
                    deadline_ms: 10_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: crate::compress::Encoded::Dense(global),
                    mask_seed: 1,
                    compression: CompressionConfig::NONE,
                },
            )
            .unwrap();
        let (_, up) = server
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .unwrap();
        match up {
            Msg::Update { delta, stats, .. } => {
                assert_eq!(
                    crate::compress::decompress(&delta, n_params).unwrap().len(),
                    n_params
                );
                assert!(stats.steps > 0);
                assert!(stats.compute_ms >= 0.0);
            }
            other => panic!("expected Update, got {}", other.name()),
        }
        server.send_to(0, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 1);
    }

    #[test]
    fn worker_trains_from_shared_preencoded_broadcast() {
        // the orchestrator broadcasts one pre-encoded payload per
        // round; over inproc the worker receives it still wrapped, and
        // its normal decompress path must unwrap it transparently
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic);
        let endpoint = hub.add_client(0, LinkShaper::unshaped());
        let server = hub.server();
        let rt = MockRuntime::new(12, 3);
        let n_params = rt.n_params();
        let global = rt.init(0).unwrap();
        let worker = Worker::new(
            endpoint,
            Box::new(rt),
            one_node(),
            toy_shard(12, 3, 32, 1),
            FaultInjector::disabled(),
            WorkerOptions {
                emulate_speed: false,
                ..Default::default()
            },
        );
        let handle = std::thread::spawn(move || worker.run().unwrap());
        server.recv_timeout(Duration::from_secs(5)).unwrap(); // Register
        let shared = crate::compress::Encoded::PreEncoded(
            crate::network::pre_encode_dense(&global),
        );
        server
            .send_to(
                0,
                &Msg::RoundStart {
                    round: 0,
                    model_version: 0,
                    deadline_ms: 10_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: shared,
                    mask_seed: 1,
                    compression: CompressionConfig::NONE,
                },
            )
            .unwrap();
        let (_, up) = server
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .unwrap();
        match up {
            Msg::Update { delta, stats, .. } => {
                assert_eq!(
                    crate::compress::decompress(&delta, n_params).unwrap().len(),
                    n_params
                );
                assert!(stats.steps > 0);
            }
            other => panic!("expected Update, got {}", other.name()),
        }
        server.send_to(0, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 1);
    }

    /// ISSUE 4 satellite (`reports_update` consistency): a straggling
    /// worker still reports — late, but with the base model version the
    /// server needs to weight it — while a preempted worker reports
    /// nothing, exactly as `FaultAction::reports_update` promises.
    #[test]
    fn straggler_reports_update_with_base_version() {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic);
        let endpoint = hub.add_client(0, LinkShaper::unshaped());
        let server = hub.server();
        let rt = MockRuntime::new(8, 2);
        let global = rt.init(0).unwrap();
        let injector = FaultInjector::new(
            crate::config::FaultConfig {
                straggler_prob: 1.0,
                straggler_factor: 1.0, // always straggle, but don't slow the test
                ..Default::default()
            },
            0,
        );
        assert!(injector.action(2, 0, false).reports_update());
        let worker = Worker::new(
            endpoint,
            Box::new(rt),
            one_node(),
            toy_shard(8, 2, 16, 2),
            injector,
            WorkerOptions {
                emulate_speed: false,
                ..Default::default()
            },
        );
        let handle = std::thread::spawn(move || worker.run().unwrap());
        server.recv_timeout(Duration::from_secs(5)).unwrap(); // Register
        server
            .send_to(
                0,
                &Msg::RoundStart {
                    round: 2,
                    model_version: 5, // async-style: version ≠ round
                    deadline_ms: 10_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: crate::compress::Encoded::Dense(global),
                    mask_seed: 1,
                    compression: CompressionConfig::NONE,
                },
            )
            .unwrap();
        let (_, up) = server
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .unwrap();
        match up {
            Msg::Update {
                round,
                base_version,
                ..
            } => {
                assert_eq!(round, 2);
                assert_eq!(
                    base_version, 5,
                    "update must echo the model version it trained on"
                );
            }
            other => panic!("expected Update, got {}", other.name()),
        }
        server.send_to(0, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 1);
    }

    #[test]
    fn injected_preemption_suppresses_update() {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic);
        let endpoint = hub.add_client(0, LinkShaper::unshaped());
        let server = hub.server();
        let rt = MockRuntime::new(8, 2);
        let global = rt.init(0).unwrap();
        let node = node_of("p3.2xlarge-spot");
        assert!(node.sku.preempt_per_hour > 0.0, "test needs a spot SKU");
        let injector = FaultInjector::new(
            crate::config::FaultConfig {
                preemption_prob: 1.0,
                ..Default::default()
            },
            0,
        );
        assert!(!injector.action(0, 0, true).reports_update());
        let worker = Worker::new(
            endpoint,
            Box::new(rt),
            node,
            toy_shard(8, 2, 16, 2),
            injector,
            WorkerOptions {
                emulate_speed: false,
                ..Default::default()
            },
        );
        let handle = std::thread::spawn(move || worker.run().unwrap());
        server.recv_timeout(Duration::from_secs(5)).unwrap(); // Register
        server
            .send_to(
                0,
                &Msg::RoundStart {
                    round: 0,
                    model_version: 0,
                    deadline_ms: 1_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: crate::compress::Encoded::Dense(global),
                    mask_seed: 1,
                    compression: CompressionConfig::NONE,
                },
            )
            .unwrap();
        let got = server.recv_timeout(Duration::from_millis(600)).unwrap();
        assert!(got.is_none(), "preempted client sent {got:?}");
        server.send_to(0, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 0);
    }

    #[test]
    fn injected_dropout_suppresses_update() {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic);
        let endpoint = hub.add_client(1, LinkShaper::unshaped());
        let server = hub.server();
        let rt = MockRuntime::new(8, 2);
        let global = rt.init(0).unwrap();
        let worker = Worker::new(
            endpoint,
            Box::new(rt),
            one_node(),
            toy_shard(8, 2, 16, 2),
            FaultInjector::new(
                crate::config::FaultConfig {
                    dropout_prob: 1.0, // always drop
                    ..Default::default()
                },
                0,
            ),
            WorkerOptions {
                emulate_speed: false,
                ..Default::default()
            },
        );
        let handle = std::thread::spawn(move || worker.run().unwrap());
        server.recv_timeout(Duration::from_secs(5)).unwrap(); // Register
        server
            .send_to(
                1,
                &Msg::RoundStart {
                    round: 0,
                    model_version: 0,
                    deadline_ms: 1_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: crate::compress::Encoded::Dense(global),
                    mask_seed: 1,
                    compression: CompressionConfig::NONE,
                },
            )
            .unwrap();
        // no update should arrive
        let got = server.recv_timeout(Duration::from_millis(600)).unwrap();
        assert!(got.is_none(), "dropout client sent {got:?}");
        server.send_to(1, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 0);
    }
}
