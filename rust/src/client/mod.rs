//! Federated client (paper §3.2 "Federated Clients (Workers)").
//!
//! * [`trainer`] — local training: epochs of minibatch FedProx-SGD via
//!   the model runtime, delta computation, update statistics.
//! * [`profile`] — resource profiling benchmark (paper §4.1).
//! * [`worker`] — the event loop: register → (RoundStart → train →
//!   Update)* → Shutdown, with heterogeneity emulation and fault
//!   injection applied where a real deployment would experience them.

mod profile;
mod trainer;
mod worker;

pub use profile::profile_runtime;
pub use trainer::{train_local, LocalOutcome};
pub use worker::{Worker, WorkerOptions};
