//! Resource profiling (paper §4.1): clients benchmark themselves and
//! report capacity in their registration profile. Here the benchmark
//! measures real `train_step` latency on synthetic data, then folds in
//! the node's SKU attributes (the part a real deployment reads from
//! `/proc` and NVML).

use crate::cluster::Node;
use crate::data::{Batch, Shard};
use crate::network::ClientProfile;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use anyhow::Result;

/// Benchmark `runtime` and assemble the registration profile.
pub fn profile_runtime(
    runtime: &dyn ModelRuntime,
    node: &Node,
    shard: &Shard,
    bench_steps: usize,
) -> Result<ClientProfile> {
    let bench_step_ms = if bench_steps > 0 {
        let params = runtime.init(0xBEAC)?;
        let b = runtime.train_batch();
        let mut rng = Rng::new(0xBEAC);
        // synthetic batch with the shard's shape
        let mut x = Vec::with_capacity(b * shard.x_len);
        let mut y = Vec::with_capacity(b * shard.y_len);
        for _ in 0..b {
            for _ in 0..shard.x_len {
                x.push(rng.normal() as f32);
            }
            for _ in 0..shard.y_len {
                y.push(rng.below(4) as i32);
            }
        }
        let batch = Batch { x, y, n: b };
        let t0 = std::time::Instant::now();
        let mut p = params;
        for _ in 0..bench_steps {
            p = runtime.train_step(&p, &p.clone(), &batch, 0.01, 0.0)?.params;
        }
        t0.elapsed().as_secs_f64() * 1e3 / bench_steps as f64
    } else {
        1.0
    };
    let (bw, _) = node.link().profile();
    Ok(ClientProfile {
        speed_factor: node.speed_factor,
        mem_gb: node.sku.mem_gb,
        link_bw: bw,
        n_samples: shard.n as u64,
        bench_step_ms: bench_step_ms / node.speed_factor.max(1e-6),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::ClusterConfig;
    use crate::runtime::MockRuntime;

    fn shard(n: usize, dim: usize) -> Shard {
        Shard {
            x: vec![0.5; n * dim],
            y: vec![0; n],
            n,
            x_len: dim,
            y_len: 1,
        }
    }

    #[test]
    fn profile_reflects_node_attributes() {
        let cluster = Cluster::build(
            &ClusterConfig {
                nodes: vec![("hpc-rtx6000".into(), 1), ("t3.large".into(), 1)],
                cloud_backend: "inproc".into(),
                hpc_backend: "inproc".into(),
            },
            0,
        )
        .unwrap();
        let rt = MockRuntime::new(16, 4);
        let s = shard(50, 16);
        let fast = profile_runtime(&rt, &cluster.nodes[0], &s, 2).unwrap();
        let slow = profile_runtime(&rt, &cluster.nodes[1], &s, 2).unwrap();
        assert_eq!(fast.n_samples, 50);
        // t3.large (speed ~0.02) reports a much slower effective step
        assert!(slow.bench_step_ms > 5.0 * fast.bench_step_ms);
        assert!(fast.link_bw > slow.link_bw);
        assert!(fast.mem_gb > 0.0);
    }

    #[test]
    fn zero_bench_steps_is_allowed() {
        let cluster = Cluster::build(
            &ClusterConfig {
                nodes: vec![("hpc-cpu".into(), 1)],
                cloud_backend: "inproc".into(),
                hpc_backend: "inproc".into(),
            },
            1,
        )
        .unwrap();
        let rt = MockRuntime::new(8, 2);
        let p = profile_runtime(&rt, &cluster.nodes[0], &shard(10, 8), 0).unwrap();
        assert!(p.bench_step_ms > 0.0);
    }
}
