//! Local training (Algorithm 1 lines 7–8): `local_epochs` epochs of
//! minibatch steps starting from the global model, then
//! `Δ_c = w_local − M_r` plus the statistics weighted aggregation needs.

use crate::data::{BatchIter, Shard};
use crate::runtime::ModelRuntime;
use anyhow::Result;

/// Result of one client's local round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    /// Δ_c = trained params − global params.
    pub delta: Vec<f32>,
    pub train_loss: f32,
    pub steps: u32,
    /// Variance of delta entries (inverse-variance weighting signal).
    pub update_var: f32,
    pub n_samples: u64,
}

/// Run local training. `stop_after_frac` < 1.0 simulates a mid-round
/// preemption: training truncates after that fraction of steps and the
/// caller decides whether anything is reported.
#[allow(clippy::too_many_arguments)]
pub fn train_local(
    runtime: &dyn ModelRuntime,
    shard: &Shard,
    global: &[f32],
    local_epochs: usize,
    lr: f32,
    mu: f32,
    seed: u64,
    stop_after_frac: f64,
) -> Result<LocalOutcome> {
    let mut params = global.to_vec();
    let batch_size = runtime.train_batch();
    let mut iter = BatchIter::new(shard, batch_size, seed);
    let steps_per_epoch = iter.batches_per_epoch();
    let total_steps = (steps_per_epoch * local_epochs).max(1);
    let run_steps = ((total_steps as f64 * stop_after_frac).floor() as usize).min(total_steps);

    let mut loss_acc = 0f64;
    let mut done = 0u32;
    for _ in 0..run_steps {
        let batch = iter.next_batch();
        let out = runtime.train_step(&params, global, &batch, lr, mu)?;
        params = out.params;
        loss_acc += out.loss as f64;
        done += 1;
    }

    let mut delta = params;
    for (d, &g) in delta.iter_mut().zip(global) {
        *d -= g;
    }
    // variance of delta entries
    let n = delta.len().max(1) as f64;
    let mean: f64 = delta.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var: f64 = delta
        .iter()
        .map(|&x| (x as f64 - mean) * (x as f64 - mean))
        .sum::<f64>()
        / n;

    Ok(LocalOutcome {
        delta,
        train_loss: if done > 0 {
            (loss_acc / done as f64) as f32
        } else {
            f32::NAN
        },
        steps: done,
        update_var: var as f32,
        n_samples: shard.n as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;
    use crate::util::rng::Rng;

    fn toy_shard(rt: &MockRuntime, n: usize, seed: u64) -> Shard {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * rt.dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(rt.classes);
            for j in 0..rt.dim {
                let base = if j % rt.classes == cls { 1.5 } else { 0.0 };
                x.push(base + 0.3 * rng.normal() as f32);
            }
            y.push(cls as i32);
        }
        Shard {
            x,
            y,
            n,
            x_len: rt.dim,
            y_len: 1,
        }
    }

    #[test]
    fn trains_and_returns_nonzero_delta() {
        let rt = MockRuntime::new(20, 4);
        let global = rt.init(0).unwrap();
        let shard = toy_shard(&rt, 48, 1);
        let out = train_local(&rt, &shard, &global, 2, 0.1, 0.0, 7, 1.0).unwrap();
        assert_eq!(out.delta.len(), global.len());
        assert_eq!(out.n_samples, 48);
        let steps_per_epoch = 48usize.div_ceil(rt.train_batch());
        assert_eq!(out.steps as usize, 2 * steps_per_epoch);
        let norm: f64 = out.delta.iter().map(|&d| (d * d) as f64).sum();
        assert!(norm > 0.0, "delta is zero — no training happened");
        assert!(out.train_loss.is_finite());
        assert!(out.update_var >= 0.0);
    }

    #[test]
    fn preemption_truncates_steps() {
        let rt = MockRuntime::new(20, 4);
        let global = rt.init(0).unwrap();
        let shard = toy_shard(&rt, 64, 2);
        let full = train_local(&rt, &shard, &global, 2, 0.1, 0.0, 7, 1.0).unwrap();
        let half = train_local(&rt, &shard, &global, 2, 0.1, 0.0, 7, 0.5).unwrap();
        assert!(half.steps < full.steps);
        assert!(half.steps > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let rt = MockRuntime::new(10, 3);
        let global = rt.init(1).unwrap();
        let shard = toy_shard(&rt, 32, 3);
        let a = train_local(&rt, &shard, &global, 1, 0.05, 0.0, 9, 1.0).unwrap();
        let b = train_local(&rt, &shard, &global, 1, 0.05, 0.0, 9, 1.0).unwrap();
        assert_eq!(a.delta, b.delta);
        let c = train_local(&rt, &shard, &global, 1, 0.05, 0.0, 10, 1.0).unwrap();
        assert_ne!(a.delta, c.delta);
    }

    #[test]
    fn fedprox_shrinks_delta() {
        let rt = MockRuntime::new(20, 4);
        let global = rt.init(0).unwrap();
        let shard = toy_shard(&rt, 48, 4);
        let free = train_local(&rt, &shard, &global, 3, 0.1, 0.0, 5, 1.0).unwrap();
        let prox = train_local(&rt, &shard, &global, 3, 0.1, 2.0, 5, 1.0).unwrap();
        let norm = |v: &[f32]| v.iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt();
        assert!(
            norm(&prox.delta) < norm(&free.delta),
            "prox {} !< free {}",
            norm(&prox.delta),
            norm(&free.delta)
        );
    }
}
