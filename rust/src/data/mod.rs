//! Dataset substrate (substitution for CIFAR-10 / LEAF-Shakespeare /
//! MedMNIST downloads — see DESIGN.md §1).
//!
//! Three synthetic workloads with the same shapes and class structure
//! as the paper's datasets, plus the paper's non-IID partitioners.
//! Generators are learnable-by-construction (class-conditional
//! structure with controlled noise) so accuracy curves behave like the
//! real thing: models beat chance quickly, non-IID partitions hurt
//! FedAvg more than FedProx, and harder tasks converge slower.

mod loader;
mod partition;
mod shakespeare;
mod synthetic;

pub use loader::BatchIter;
pub use partition::{partition_indices, PartitionStats};
pub use shakespeare::CharCorpus;
pub use synthetic::{ImageTask, SyntheticImages};

use crate::config::DataConfig;
#[cfg(test)]
use crate::config::Partition;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// One minibatch in the runtime's wire layout: flat row-major features
/// + integer labels. `x` is f32 for image tasks and holds casted token
/// ids for char-LM tasks (the runtime re-encodes to the artifact's
/// input dtype).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Number of examples (rows) in this batch.
    pub n: usize,
}

/// A client's local shard or the central eval set.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Row-major feature matrix, `n * x_len`.
    pub x: Vec<f32>,
    /// Labels: one per example for images; `seq_len` per example for LM.
    pub y: Vec<i32>,
    pub n: usize,
    pub x_len: usize,
    pub y_len: usize,
}

impl Shard {
    pub fn example(&self, i: usize) -> (&[f32], &[i32]) {
        (
            &self.x[i * self.x_len..(i + 1) * self.x_len],
            &self.y[i * self.y_len..(i + 1) * self.y_len],
        )
    }

    /// Class histogram (image tasks; first label per example for LM).
    pub fn label_histogram(&self, n_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; n_classes];
        for i in 0..self.n {
            let (_, y) = self.example(i);
            let c = y[0] as usize;
            if c < n_classes {
                h[c] += 1;
            }
        }
        h
    }
}

/// A federated dataset: per-client shards + a centralized eval set
/// (paper §5.3 evaluates on a centralized held-out set).
#[derive(Debug, Clone)]
pub struct FederatedDataset {
    pub clients: Vec<Shard>,
    pub eval: Shard,
    pub n_classes: usize,
    pub name: String,
}

impl FederatedDataset {
    /// Build the workload matching `cfg.dataset` for `n_clients`
    /// clients. Deterministic in `seed`.
    pub fn build(cfg: &DataConfig, n_clients: usize, seed: u64) -> Result<FederatedDataset> {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        match cfg.dataset.as_str() {
            "cifar_cnn" => Ok(build_image(
                ImageTask::Cifar,
                cfg,
                n_clients,
                &mut rng,
                "cifar_cnn",
            )),
            "medmnist_mlp" => Ok(build_image(
                ImageTask::MedMnist,
                cfg,
                n_clients,
                &mut rng,
                "medmnist_mlp",
            )),
            "charlm" => Ok(shakespeare::build_charlm(
                cfg, n_clients, /*seq=*/ 32, /*vocab=*/ 64, &mut rng, "charlm",
            )),
            "e2e_charlm" => Ok(shakespeare::build_charlm(
                cfg, n_clients, /*seq=*/ 128, /*vocab=*/ 96, &mut rng, "e2e_charlm",
            )),
            other => bail!("unknown dataset '{other}'"),
        }
    }
}

fn build_image(
    task: ImageTask,
    cfg: &DataConfig,
    n_clients: usize,
    rng: &mut Rng,
    name: &str,
) -> FederatedDataset {
    let gen = SyntheticImages::new(task, rng.next_u64());
    let n_classes = gen.n_classes();
    // generate a global pool, then partition per the configured scheme
    let total = cfg.samples_per_client * n_clients;
    let (xs, ys) = gen.generate(total, rng);
    let assignment = partition_indices(&ys, n_clients, n_classes, cfg.partition, rng);
    let x_len = gen.x_len();
    let mut clients = Vec::with_capacity(n_clients);
    for idxs in &assignment {
        let mut x = Vec::with_capacity(idxs.len() * x_len);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(&xs[i * x_len..(i + 1) * x_len]);
            y.push(ys[i]);
        }
        clients.push(Shard {
            n: idxs.len(),
            x,
            y,
            x_len,
            y_len: 1,
        });
    }
    // centralized IID eval set from the same generator
    let (ex, ey) = gen.generate(cfg.eval_samples, rng);
    let eval = Shard {
        n: cfg.eval_samples,
        x: ex,
        y: ey,
        x_len,
        y_len: 1,
    };
    FederatedDataset {
        clients,
        eval,
        n_classes,
        name: name.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dc(dataset: &str, partition: Partition) -> DataConfig {
        DataConfig {
            dataset: dataset.into(),
            partition,
            samples_per_client: 64,
            eval_samples: 128,
        }
    }

    #[test]
    fn build_all_datasets() {
        for name in ["cifar_cnn", "medmnist_mlp", "charlm"] {
            let fd = FederatedDataset::build(&dc(name, Partition::Iid), 4, 1).unwrap();
            assert_eq!(fd.clients.len(), 4);
            assert!(fd.eval.n > 0);
            for c in &fd.clients {
                assert!(c.n > 0);
                assert_eq!(c.x.len(), c.n * c.x_len);
                assert_eq!(c.y.len(), c.n * c.y_len);
            }
        }
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(FederatedDataset::build(&dc("imagenet", Partition::Iid), 2, 0).is_err());
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = dc("medmnist_mlp", Partition::Iid);
        let a = FederatedDataset::build(&cfg, 3, 9).unwrap();
        let b = FederatedDataset::build(&cfg, 3, 9).unwrap();
        assert_eq!(a.clients[0].x, b.clients[0].x);
        let c = FederatedDataset::build(&cfg, 3, 10).unwrap();
        assert_ne!(a.clients[0].x, c.clients[0].x);
    }

    #[test]
    fn label_shard_limits_classes_per_client() {
        let cfg = dc(
            "cifar_cnn",
            Partition::LabelShard {
                classes_per_client: 2,
            },
        );
        let fd = FederatedDataset::build(&cfg, 6, 3).unwrap();
        for c in &fd.clients {
            let h = c.label_histogram(fd.n_classes);
            let present = h.iter().filter(|&&n| n > 0).count();
            assert!(present <= 3, "client saw {present} classes"); // 2–3 per paper
            assert!(present >= 1);
        }
    }

    #[test]
    fn shard_example_slicing() {
        let s = Shard {
            x: (0..12).map(|v| v as f32).collect(),
            y: vec![0, 1, 2],
            n: 3,
            x_len: 4,
            y_len: 1,
        };
        let (x1, y1) = s.example(1);
        assert_eq!(x1, &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(y1, &[1]);
    }
}
