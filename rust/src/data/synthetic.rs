//! Synthetic image tasks with CIFAR-10 / MedMNIST shapes.
//!
//! Each class is a smooth random "prototype" image (per-class frequency
//! mixture) plus per-sample noise and a random affine jitter. The
//! signal-to-noise ratio is tuned so a small CNN/MLP reaches high
//! accuracy in a few hundred steps but not instantly — mimicking the
//! difficulty ordering of the real datasets (MedMNIST easier than
//! CIFAR-10, as in the paper's Table 2).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImageTask {
    /// 32×32×3, 10 classes, noisier (harder).
    Cifar,
    /// 28×28×1, 10 classes, cleaner textures (easier).
    MedMnist,
}

/// Class-conditional synthetic image generator.
pub struct SyntheticImages {
    task: ImageTask,
    /// Per-class prototype images.
    prototypes: Vec<Vec<f32>>,
    noise: f32,
}

impl SyntheticImages {
    pub fn new(task: ImageTask, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x1A46E5);
        let (h, w, c) = Self::dims_of(task);
        let n_classes = 10;
        let noise = match task {
            ImageTask::Cifar => 0.9,
            ImageTask::MedMnist => 0.55,
        };
        // smooth prototypes: sum of a few random low-frequency waves per
        // channel, so nearby pixels correlate like natural images
        let mut prototypes = Vec::with_capacity(n_classes);
        for _ in 0..n_classes {
            let mut img = vec![0f32; h * w * c];
            for ch in 0..c {
                for _ in 0..4 {
                    let fx = rng.f64() * 3.0 + 0.5;
                    let fy = rng.f64() * 3.0 + 0.5;
                    let px = rng.f64() * std::f64::consts::TAU;
                    let py = rng.f64() * std::f64::consts::TAU;
                    let amp = 0.5 + 0.5 * rng.f64();
                    for y in 0..h {
                        for x in 0..w {
                            let v = amp
                                * ((fx * x as f64 / w as f64 * std::f64::consts::TAU + px)
                                    .sin()
                                    * (fy * y as f64 / h as f64 * std::f64::consts::TAU + py)
                                        .cos());
                            img[(y * w + x) * c + ch] += v as f32;
                        }
                    }
                }
            }
            prototypes.push(img);
        }
        SyntheticImages {
            task,
            prototypes,
            noise,
        }
    }

    fn dims_of(task: ImageTask) -> (usize, usize, usize) {
        match task {
            ImageTask::Cifar => (32, 32, 3),
            ImageTask::MedMnist => (28, 28, 1),
        }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        Self::dims_of(self.task)
    }

    pub fn x_len(&self) -> usize {
        let (h, w, c) = self.dims();
        h * w * c
    }

    pub fn n_classes(&self) -> usize {
        self.prototypes.len()
    }

    /// Generate `n` labeled samples (uniform class mix).
    pub fn generate(&self, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let x_len = self.x_len();
        let mut xs = Vec::with_capacity(n * x_len);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(self.n_classes());
            ys.push(cls as i32);
            self.sample_into(cls, rng, &mut xs);
        }
        (xs, ys)
    }

    /// Generate one sample of class `cls`, appending to `out`.
    pub fn sample_into(&self, cls: usize, rng: &mut Rng, out: &mut Vec<f32>) {
        let (h, w, c) = self.dims();
        let proto = &self.prototypes[cls];
        // small translation jitter: shift by up to ±2 px
        let dx = rng.below(5) as isize - 2;
        let dy = rng.below(5) as isize - 2;
        let gain = 1.0 + 0.15 * rng.normal() as f32;
        for y in 0..h as isize {
            for x in 0..w as isize {
                let sy = (y + dy).rem_euclid(h as isize) as usize;
                let sx = (x + dx).rem_euclid(w as isize) as usize;
                for ch in 0..c {
                    let base = proto[(sy * w + sx) * c + ch];
                    let v = gain * base + self.noise * rng.normal() as f32;
                    out.push(v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_datasets() {
        let c = SyntheticImages::new(ImageTask::Cifar, 0);
        assert_eq!(c.x_len(), 32 * 32 * 3);
        assert_eq!(c.n_classes(), 10);
        let m = SyntheticImages::new(ImageTask::MedMnist, 0);
        assert_eq!(m.x_len(), 28 * 28);
    }

    #[test]
    fn generate_counts_and_label_range() {
        let g = SyntheticImages::new(ImageTask::MedMnist, 1);
        let mut rng = Rng::new(2);
        let (xs, ys) = g.generate(50, &mut rng);
        assert_eq!(xs.len(), 50 * g.x_len());
        assert_eq!(ys.len(), 50);
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
        assert!(xs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin — the learnability guarantee
        let g = SyntheticImages::new(ImageTask::Cifar, 3);
        let mut rng = Rng::new(4);
        let n = 200;
        let (xs, ys) = g.generate(n, &mut rng);
        let x_len = g.x_len();
        let mut correct = 0;
        for i in 0..n {
            let x = &xs[i * x_len..(i + 1) * x_len];
            let mut best = (f32::INFINITY, 0usize);
            for (c, p) in g.prototypes.iter().enumerate() {
                let d: f32 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == ys[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc} ≤ 0.5");
    }

    #[test]
    fn medmnist_cleaner_than_cifar() {
        assert!(
            SyntheticImages::new(ImageTask::MedMnist, 0).noise
                < SyntheticImages::new(ImageTask::Cifar, 0).noise
        );
    }

    #[test]
    fn deterministic_prototypes() {
        let a = SyntheticImages::new(ImageTask::Cifar, 7);
        let b = SyntheticImages::new(ImageTask::Cifar, 7);
        assert_eq!(a.prototypes[0], b.prototypes[0]);
        let c = SyntheticImages::new(ImageTask::Cifar, 8);
        assert_ne!(a.prototypes[0], c.prototypes[0]);
    }
}
