//! Minibatch iteration over a [`Shard`]: shuffled epochs, fixed batch
//! size (the AOT artifacts have static shapes), last partial batch
//! padded by wrapping — every example still seen once per epoch.

use super::{Batch, Shard};
use crate::util::rng::Rng;

/// Epoch-based batch iterator.
pub struct BatchIter<'a> {
    shard: &'a Shard,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(shard: &'a Shard, batch: usize, seed: u64) -> Self {
        assert!(batch > 0);
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let mut order: Vec<usize> = (0..shard.n).collect();
        rng.shuffle(&mut order);
        BatchIter {
            shard,
            batch,
            order,
            cursor: 0,
            rng,
        }
    }

    /// Batches per epoch (ceil).
    pub fn batches_per_epoch(&self) -> usize {
        self.shard.n.div_ceil(self.batch)
    }

    /// Next batch; reshuffles and wraps at epoch end. The batch is
    /// always exactly `batch` rows (static artifact shapes): the final
    /// short batch is completed with examples from the epoch start.
    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch;
        let mut x = Vec::with_capacity(b * self.shard.x_len);
        let mut y = Vec::with_capacity(b * self.shard.y_len);
        for k in 0..b {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            // wrap within the same call for shards smaller than a batch
            let i = self.order[(self.cursor + 0) % self.order.len()];
            self.cursor += 1;
            let (ex, ey) = self.shard.example(i);
            x.extend_from_slice(ex);
            y.extend_from_slice(ey);
            let _ = k;
        }
        Batch { x, y, n: b }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(n: usize) -> Shard {
        Shard {
            x: (0..n * 2).map(|v| v as f32).collect(),
            y: (0..n as i32).collect(),
            n,
            x_len: 2,
            y_len: 1,
        }
    }

    #[test]
    fn batches_have_static_shape() {
        let s = shard(10);
        let mut it = BatchIter::new(&s, 4, 0);
        for _ in 0..6 {
            let b = it.next_batch();
            assert_eq!(b.n, 4);
            assert_eq!(b.x.len(), 8);
            assert_eq!(b.y.len(), 4);
        }
    }

    #[test]
    fn epoch_sees_every_example() {
        let s = shard(12);
        let mut it = BatchIter::new(&s, 4, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..it.batches_per_epoch() {
            for y in it.next_batch().y {
                seen.insert(y);
            }
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn shard_smaller_than_batch_wraps() {
        let s = shard(3);
        let mut it = BatchIter::new(&s, 8, 2);
        let b = it.next_batch();
        assert_eq!(b.n, 8);
        let distinct: std::collections::HashSet<i32> = b.y.iter().copied().collect();
        assert_eq!(distinct.len(), 3);
    }

    #[test]
    fn different_seeds_differ() {
        let s = shard(32);
        let a = BatchIter::new(&s, 8, 3).next_batch();
        let b = BatchIter::new(&s, 8, 4).next_batch();
        assert_ne!(a.y, b.y);
    }

    #[test]
    fn deterministic_replay() {
        let s = shard(32);
        let mut i1 = BatchIter::new(&s, 8, 5);
        let mut i2 = BatchIter::new(&s, 8, 5);
        for _ in 0..10 {
            assert_eq!(i1.next_batch(), i2.next_batch());
        }
    }
}
