//! Non-IID partitioners (paper §5.2): split a labeled pool across
//! clients under IID, label-shard (2–3 classes per client) or
//! Dirichlet(α) schemes.

use crate::config::Partition;
use crate::util::rng::Rng;

/// Assign pool indices to clients. Returns one index list per client.
/// Every pool element is assigned to exactly one client.
pub fn partition_indices(
    labels: &[i32],
    n_clients: usize,
    n_classes: usize,
    scheme: Partition,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_clients > 0);
    match scheme {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..labels.len()).collect();
            rng.shuffle(&mut idx);
            round_robin(&idx, n_clients)
        }
        Partition::LabelShard { classes_per_client } => {
            label_shard(labels, n_clients, n_classes, classes_per_client, rng)
        }
        Partition::Dirichlet { alpha } => {
            dirichlet(labels, n_clients, n_classes, alpha, rng)
        }
    }
}

fn round_robin(idx: &[usize], n_clients: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::with_capacity(idx.len() / n_clients + 1); n_clients];
    for (i, &v) in idx.iter().enumerate() {
        out[i % n_clients].push(v);
    }
    out
}

/// Paper-style label sharding: each client is granted 2–3 classes
/// (`classes_per_client` ± 1, clamped), then class pools are dealt out
/// among the clients holding that class.
fn label_shard(
    labels: &[i32],
    n_clients: usize,
    n_classes: usize,
    classes_per_client: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    // per-class index pools, shuffled
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[(l as usize).min(n_classes - 1)].push(i);
    }
    for p in &mut pools {
        rng.shuffle(p);
    }
    // grant class sets: client c gets classes_per_client (sometimes +1,
    // reproducing the paper's "2–3 classes") distinct classes
    let mut grants: Vec<Vec<usize>> = Vec::with_capacity(n_clients);
    for _ in 0..n_clients {
        let k = (classes_per_client + usize::from(rng.chance(0.5))).min(n_classes);
        grants.push(rng.sample_indices(n_classes, k));
    }
    // ensure every class is granted to at least one client so no data
    // is stranded
    for cls in 0..n_classes {
        if !grants.iter().any(|g| g.contains(&cls)) {
            let c = rng.below(n_clients);
            grants[c].push(cls);
        }
    }
    // deal each class pool among its holders
    let mut out = vec![Vec::new(); n_clients];
    for cls in 0..n_classes {
        let holders: Vec<usize> = (0..n_clients)
            .filter(|&c| grants[c].contains(&cls))
            .collect();
        for (i, &idx) in pools[cls].iter().enumerate() {
            out[holders[i % holders.len()]].push(idx);
        }
    }
    out
}

/// Dirichlet(α) partition: for each class, split its pool according to
/// a Dirichlet draw over clients (the standard FL benchmark scheme).
fn dirichlet(
    labels: &[i32],
    n_clients: usize,
    n_classes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[(l as usize).min(n_classes - 1)].push(i);
    }
    let mut out = vec![Vec::new(); n_clients];
    for pool in pools.iter_mut() {
        rng.shuffle(pool);
        let weights = rng.dirichlet(alpha, n_clients);
        // convert weights to contiguous slice boundaries
        let mut start = 0usize;
        for (c, w) in weights.iter().enumerate() {
            let take = if c + 1 == n_clients {
                pool.len() - start
            } else {
                ((w * pool.len() as f64).round() as usize).min(pool.len() - start)
            };
            out[c].extend_from_slice(&pool[start..start + take]);
            start += take;
        }
    }
    out
}

/// Heterogeneity diagnostics for a partition (used in logs + tests).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionStats {
    /// Per-client sample counts.
    pub counts: Vec<usize>,
    /// Mean number of distinct classes per client.
    pub mean_classes_per_client: f64,
    /// Max/min count ratio (imbalance).
    pub imbalance: f64,
}

impl PartitionStats {
    pub fn compute(assignment: &[Vec<usize>], labels: &[i32], n_classes: usize) -> Self {
        let counts: Vec<usize> = assignment.iter().map(|a| a.len()).collect();
        let mut class_counts = 0usize;
        for a in assignment {
            let mut seen = vec![false; n_classes];
            for &i in a {
                seen[(labels[i] as usize).min(n_classes - 1)] = true;
            }
            class_counts += seen.iter().filter(|&&s| s).count();
        }
        let max = *counts.iter().max().unwrap_or(&0) as f64;
        let min = *counts.iter().min().unwrap_or(&0) as f64;
        PartitionStats {
            mean_classes_per_client: class_counts as f64 / assignment.len() as f64,
            imbalance: if min > 0.0 { max / min } else { f64::INFINITY },
            counts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, n_classes: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(n_classes) as i32).collect()
    }

    fn assert_exact_cover(assign: &[Vec<usize>], n: usize) {
        let mut seen = vec![false; n];
        for a in assign {
            for &i in a {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some indices unassigned");
    }

    #[test]
    fn iid_covers_and_balances() {
        let l = labels(1000, 10, 0);
        let mut rng = Rng::new(1);
        let a = partition_indices(&l, 7, 10, Partition::Iid, &mut rng);
        assert_exact_cover(&a, 1000);
        let stats = PartitionStats::compute(&a, &l, 10);
        assert!(stats.imbalance < 1.05);
        assert!(stats.mean_classes_per_client > 9.0);
    }

    #[test]
    fn label_shard_covers_and_restricts() {
        let l = labels(2000, 10, 2);
        let mut rng = Rng::new(3);
        let a = partition_indices(
            &l,
            8,
            10,
            Partition::LabelShard {
                classes_per_client: 2,
            },
            &mut rng,
        );
        assert_exact_cover(&a, 2000);
        let stats = PartitionStats::compute(&a, &l, 10);
        // paper: 2–3 classes per client (a few may pick up stranded classes)
        assert!(
            stats.mean_classes_per_client <= 3.5,
            "mean classes {}",
            stats.mean_classes_per_client
        );
        assert!(stats.mean_classes_per_client >= 1.5);
    }

    #[test]
    fn dirichlet_covers_and_skews_with_small_alpha() {
        let l = labels(3000, 10, 4);
        let mut rng = Rng::new(5);
        let skew = partition_indices(&l, 6, 10, Partition::Dirichlet { alpha: 0.1 }, &mut rng);
        assert_exact_cover(&skew, 3000);
        let s_skew = PartitionStats::compute(&skew, &l, 10);

        let mut rng2 = Rng::new(5);
        let flat = partition_indices(
            &l,
            6,
            10,
            Partition::Dirichlet { alpha: 100.0 },
            &mut rng2,
        );
        assert_exact_cover(&flat, 3000);
        let s_flat = PartitionStats::compute(&flat, &l, 10);
        assert!(
            s_skew.mean_classes_per_client < s_flat.mean_classes_per_client,
            "alpha=0.1 ({}) should be more skewed than alpha=100 ({})",
            s_skew.mean_classes_per_client,
            s_flat.mean_classes_per_client
        );
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let l = labels(500, 10, 6);
        let a = partition_indices(
            &l,
            4,
            10,
            Partition::LabelShard {
                classes_per_client: 2,
            },
            &mut Rng::new(7),
        );
        let b = partition_indices(
            &l,
            4,
            10,
            Partition::LabelShard {
                classes_per_client: 2,
            },
            &mut Rng::new(7),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn single_client_gets_everything() {
        let l = labels(100, 10, 8);
        for scheme in [
            Partition::Iid,
            Partition::LabelShard {
                classes_per_client: 2,
            },
            Partition::Dirichlet { alpha: 0.5 },
        ] {
            let a = partition_indices(&l, 1, 10, scheme, &mut Rng::new(9));
            assert_eq!(a.len(), 1);
            assert_eq!(a[0].len(), 100);
        }
    }
}
