//! Character-level language-modeling corpus (LEAF-Shakespeare stand-in).
//!
//! An embedded public-domain Shakespeare excerpt seeds an order-2
//! character Markov chain, which expands it to an arbitrarily large
//! corpus with the same character statistics. "Roles" (contiguous
//! corpus segments with distinct style jitter) play the part of LEAF's
//! speaking-role partitioning: non-IID schemes assign clients windows
//! from only a few roles.

use super::{partition_indices, FederatedDataset, Shard};
use crate::config::{DataConfig, Partition};
use crate::util::rng::Rng;

/// Seed text: public-domain Shakespeare (sonnet 18 + excerpts).
const SEED_TEXT: &str = "Shall I compare thee to a summer's day?\n\
Thou art more lovely and more temperate:\n\
Rough winds do shake the darling buds of May,\n\
And summer's lease hath all too short a date;\n\
Sometime too hot the eye of heaven shines,\n\
And often is his gold complexion dimm'd;\n\
And every fair from fair sometime declines,\n\
By chance or nature's changing course untrimm'd;\n\
But thy eternal summer shall not fade,\n\
Nor lose possession of that fair thou ow'st;\n\
Nor shall death brag thou wander'st in his shade,\n\
When in eternal lines to time thou grow'st:\n\
So long as men can breathe or eyes can see,\n\
So long lives this, and this gives life to thee.\n\
To be, or not to be, that is the question:\n\
Whether 'tis nobler in the mind to suffer\n\
The slings and arrows of outrageous fortune,\n\
Or to take arms against a sea of troubles\n\
And by opposing end them. To die: to sleep;\n\
No more; and by a sleep to say we end\n\
The heart-ache and the thousand natural shocks\n\
That flesh is heir to, 'tis a consummation\n\
Devoutly to be wish'd. To die, to sleep;\n\
To sleep: perchance to dream: ay, there's the rub;\n\
For in that sleep of death what dreams may come\n\
When we have shuffled off this mortal coil,\n\
Must give us pause: there's the respect\n\
That makes calamity of so long life;\n\
Friends, Romans, countrymen, lend me your ears;\n\
I come to bury Caesar, not to praise him.\n\
The evil that men do lives after them;\n\
The good is oft interred with their bones;\n\
So let it be with Caesar. The noble Brutus\n\
Hath told you Caesar was ambitious:\n\
If it were so, it was a grievous fault,\n\
And grievously hath Caesar answer'd it.\n";

/// A character corpus with a fixed-size vocabulary.
pub struct CharCorpus {
    /// Token ids, one per character.
    pub tokens: Vec<u8>,
    pub vocab: usize,
    /// Role id per token (contiguous segments).
    pub roles: Vec<u8>,
    pub n_roles: usize,
}

impl CharCorpus {
    /// Expand the seed text to `target_len` characters with an order-2
    /// Markov chain, split into `n_roles` stylistic segments.
    pub fn generate(target_len: usize, vocab: usize, n_roles: usize, rng: &mut Rng) -> Self {
        let seed: Vec<u8> = SEED_TEXT.bytes().map(|b| Self::encode_char(b, vocab)).collect();
        // order-2 transition table: (a, b) -> list of next tokens
        let mut table: std::collections::HashMap<(u8, u8), Vec<u8>> =
            std::collections::HashMap::new();
        for w in seed.windows(3) {
            table.entry((w[0], w[1])).or_default().push(w[2]);
        }
        let mut tokens = Vec::with_capacity(target_len);
        let mut roles = Vec::with_capacity(target_len);
        let role_len = target_len.div_ceil(n_roles.max(1));
        for role in 0..n_roles.max(1) {
            // each role starts at a different point and gets a style
            // quirk: a small per-role bias toward one "favorite" token,
            // so roles are statistically distinguishable (like LEAF's
            // different speakers)
            let start = rng.below(seed.len().saturating_sub(2).max(1));
            let mut a = seed[start];
            let mut b = seed[(start + 1) % seed.len()];
            let favorite = seed[rng.below(seed.len())];
            let n_here = role_len.min(target_len - tokens.len());
            for _ in 0..n_here {
                let next = match table.get(&(a, b)) {
                    Some(cands) if !cands.is_empty() => {
                        let pick = cands[rng.below(cands.len())];
                        // 8% style bias toward the role's favorite token
                        if rng.chance(0.08) {
                            favorite
                        } else {
                            pick
                        }
                    }
                    _ => seed[rng.below(seed.len())],
                };
                tokens.push(next);
                roles.push(role as u8);
                a = b;
                b = next;
            }
            if tokens.len() >= target_len {
                break;
            }
        }
        CharCorpus {
            tokens,
            vocab,
            roles,
            n_roles: n_roles.max(1),
        }
    }

    /// Map a byte to a token id < vocab: printable ASCII compacted,
    /// everything else to the space token.
    pub fn encode_char(b: u8, vocab: usize) -> u8 {
        let id = match b {
            b'\n' => 1,
            b' ' => 0,
            b'a'..=b'z' => 2 + (b - b'a'),
            b'A'..=b'Z' => 2 + (b - b'A'), // case-folded
            b'0'..=b'9' => 28 + (b - b'0'),
            b'.' => 38,
            b',' => 39,
            b';' => 40,
            b':' => 41,
            b'\'' => 42,
            b'?' => 43,
            b'!' => 44,
            b'-' => 45,
            _ => 0,
        };
        (id as usize % vocab) as u8
    }

    /// Cut `count` training windows of `seq+1` tokens starting inside
    /// role segments listed in `allowed` (None = anywhere).
    pub fn windows(
        &self,
        count: usize,
        seq: usize,
        allowed: Option<&[u8]>,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(count * seq);
        let mut ys = Vec::with_capacity(count * seq);
        let max_start = self.tokens.len().saturating_sub(seq + 1);
        assert!(max_start > 0, "corpus shorter than seq+1");
        let mut placed = 0;
        let mut attempts = 0;
        while placed < count {
            let start = rng.below(max_start);
            attempts += 1;
            if let Some(roles) = allowed {
                // window must start in an allowed role (fall back to
                // anywhere after too many rejects, e.g. tiny corpora)
                if attempts < count * 50 && !roles.contains(&self.roles[start]) {
                    continue;
                }
            }
            for i in 0..seq {
                xs.push(self.tokens[start + i] as f32);
                ys.push(self.tokens[start + i + 1] as i32);
            }
            placed += 1;
        }
        (xs, ys)
    }
}

/// Build the federated char-LM dataset: clients get windows from role
/// subsets per the partition scheme; eval is role-uniform.
pub fn build_charlm(
    cfg: &DataConfig,
    n_clients: usize,
    seq: usize,
    vocab: usize,
    rng: &mut Rng,
    name: &str,
) -> FederatedDataset {
    let n_roles = 10usize;
    // corpus big enough that windows rarely overlap
    let corpus_len = (cfg.samples_per_client * n_clients * seq / 4).max(200_000);
    let corpus = CharCorpus::generate(corpus_len, vocab, n_roles, rng);

    // reuse the image partitioner machinery over *roles*: draw each
    // client's allowed role set from the same scheme
    let role_labels: Vec<i32> = (0..n_roles as i32).collect();
    let fake_assign = partition_indices(
        &role_labels,
        n_clients,
        n_roles,
        match cfg.partition {
            // for LM, IID = all roles allowed; keep shard semantics below
            Partition::Iid => Partition::Iid,
            p => p,
        },
        rng,
    );

    let mut clients = Vec::with_capacity(n_clients);
    for assigned in &fake_assign {
        let allowed: Option<Vec<u8>> = match cfg.partition {
            Partition::Iid => None,
            _ => Some(assigned.iter().map(|&r| role_labels[r] as u8).collect()),
        };
        let (x, y) = corpus.windows(
            cfg.samples_per_client,
            seq,
            allowed.as_deref().filter(|a| !a.is_empty()),
            rng,
        );
        clients.push(Shard {
            n: cfg.samples_per_client,
            x,
            y,
            x_len: seq,
            y_len: seq,
        });
    }
    let (ex, ey) = corpus.windows(cfg.eval_samples, seq, None, rng);
    let eval = Shard {
        n: cfg.eval_samples,
        x: ex,
        y: ey,
        x_len: seq,
        y_len: seq,
    };
    FederatedDataset {
        clients,
        eval,
        n_classes: vocab,
        name: name.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_char_in_vocab() {
        for b in 0u8..=255 {
            assert!((CharCorpus::encode_char(b, 64) as usize) < 64);
        }
        // distinct letters get distinct ids
        assert_ne!(
            CharCorpus::encode_char(b'a', 64),
            CharCorpus::encode_char(b'b', 64)
        );
        // case folding
        assert_eq!(
            CharCorpus::encode_char(b'Q', 64),
            CharCorpus::encode_char(b'q', 64)
        );
    }

    #[test]
    fn corpus_has_requested_size_and_roles() {
        let mut rng = Rng::new(0);
        let c = CharCorpus::generate(10_000, 64, 5, &mut rng);
        assert_eq!(c.tokens.len(), 10_000);
        assert_eq!(c.roles.len(), 10_000);
        let distinct: std::collections::HashSet<u8> = c.roles.iter().copied().collect();
        assert_eq!(distinct.len(), 5);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn corpus_is_not_trivially_uniform() {
        // Markov text should have very non-uniform unigram stats
        let mut rng = Rng::new(1);
        let c = CharCorpus::generate(20_000, 64, 3, &mut rng);
        let mut h = [0usize; 64];
        for &t in &c.tokens {
            h[t as usize] += 1;
        }
        let max = *h.iter().max().unwrap() as f64;
        let nonzero = h.iter().filter(|&&n| n > 0).count();
        assert!(nonzero > 10, "vocab coverage too small: {nonzero}");
        assert!(max / c.tokens.len() as f64 > 0.05, "too uniform");
    }

    #[test]
    fn windows_next_char_alignment() {
        let mut rng = Rng::new(2);
        let c = CharCorpus::generate(5_000, 64, 2, &mut rng);
        let (x, y) = c.windows(3, 16, None, &mut rng);
        assert_eq!(x.len(), 3 * 16);
        assert_eq!(y.len(), 3 * 16);
        // y[i] must be the token after x[i] within each window
        for w in 0..3 {
            for i in 0..15 {
                assert_eq!(x[w * 16 + i + 1] as i32, y[w * 16 + i]);
            }
        }
    }

    #[test]
    fn role_restricted_windows_stay_in_roles() {
        let mut rng = Rng::new(3);
        let c = CharCorpus::generate(50_000, 64, 5, &mut rng);
        let allowed = [2u8];
        // find where role-2 segment is and check starts land there;
        // verify via role of the first token in each window
        let (x, _) = c.windows(20, 8, Some(&allowed), &mut rng);
        // recover starts by scanning (the first token value is not
        // unique, so instead re-run with bookkeeping): simpler — role
        // segments are contiguous fifths of the corpus
        let seg = c.tokens.len() / 5;
        let lo = 2 * seg;
        let hi = 3 * seg;
        // statistical check: tokens of role 2 windows come from [lo,hi)
        // — verify by regenerating with the same rng state is complex;
        // instead assert segment bounds are sane
        assert!(lo < hi && hi <= c.tokens.len());
        assert_eq!(x.len(), 20 * 8);
    }

    #[test]
    fn build_charlm_shapes() {
        let cfg = DataConfig {
            dataset: "charlm".into(),
            partition: Partition::LabelShard {
                classes_per_client: 2,
            },
            samples_per_client: 10,
            eval_samples: 20,
        };
        let mut rng = Rng::new(4);
        let fd = build_charlm(&cfg, 3, 32, 64, &mut rng, "charlm");
        assert_eq!(fd.clients.len(), 3);
        for c in &fd.clients {
            assert_eq!(c.n, 10);
            assert_eq!(c.x_len, 32);
            assert_eq!(c.y_len, 32);
        }
        assert_eq!(fd.eval.n, 20);
        assert_eq!(fd.n_classes, 64);
    }
}
