//! Live observability + operator control plane (ROADMAP: "Observability
//! and operator control plane").
//!
//! Everything in `metrics::TrainingReport` is post-hoc — it exists only
//! after the run ends. This module makes a *running* fleet server
//! observable and steerable:
//!
//! * [`registry`] — a registry of atomic counters, gauges and
//!   fixed-bucket histograms. One process-wide instance
//!   ([`global()`]) backs the always-on instrumentation in the
//!   orchestrator, TCP transport, scratch pool and planner; tests and
//!   embedders can build private [`Registry`] instances.
//! * [`http`] — a hand-rolled HTTP/1.1 responder on
//!   `std::net::TcpListener` serving `GET /metrics` (Prometheus text
//!   exposition format 0.0.4), `/healthz`, `/readyz` and the operator
//!   control endpoint (`POST /control`, `GET /status`). No HTTP crate:
//!   the dependency posture stays anyhow + log.
//! * [`control`] — operator verbs (`drain`, `quiesce`, `resume`,
//!   `set-planner <spec>`, `set-strategy <spec>`, `status`) delivered
//!   through a command mailbox that the orchestrator drains at
//!   round/commit boundaries in both the sync and async_fedbuff
//!   engines. Specs are validated against the same name-keyed config
//!   registries the CLI uses *before* they are accepted.
//!
//! # Accuracy contract (relaxed ordering)
//!
//! Every hot-path increment is a single `AtomicU64` op with
//! `Ordering::Relaxed` — near-zero cost, no fence, no lock. The
//! trade-off is *point-in-time consistency, not accuracy*: each
//! individual counter is exact (no increment is ever lost), but one
//! `/metrics` scrape may observe metric A after an event and metric B
//! before it, because relaxed ops carry no cross-metric ordering. Rates
//! and totals are therefore trustworthy; exact cross-metric identities
//! (e.g. `hits + misses == takes`) hold only once the instrumented code
//! quiesces. Histograms follow the same contract per bucket: `_count`,
//! `_sum` and each `_bucket` are individually exact, momentarily
//! mutually skewed under concurrent writes.
//!
//! Telemetry is strictly read-only with respect to training state: no
//! scrape or `status` poll touches RNG streams, cohort state or model
//! bytes, so a seeded run is bit-identical with and without a live
//! scraper (pinned by `rust/tests/telemetry_determinism.rs`).

// Wire-reachable tree: the HTTP responder parses hostile network input,
// and the registry renders into those responses. Must produce `Err`,
// never a panic (fedhpc-lint enforces the wider rule; these attributes
// make the unwrap/expect subclass unwriteable even under plain clippy).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod control;
pub mod http;
pub mod registry;

pub use control::{parse_verb, ControlCmd, ControlPlane, Verb};
pub use http::TelemetryServer;
pub use registry::{
    global, names, tier_of, Counter, Gauge, Histogram, Registry, ROUND_SECONDS_BUCKETS,
    STALENESS_BUCKETS,
};
