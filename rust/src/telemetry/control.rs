//! Operator control plane: a command mailbox between the HTTP
//! responder and the orchestrator.
//!
//! The HTTP side ([`super::http`]) parses and *validates* a verb (bad
//! specs are rejected with `400` before they ever reach the training
//! loop), then enqueues a [`ControlCmd`]. The orchestrator drains the
//! mailbox at round boundaries (sync engine) and commit boundaries
//! (async_fedbuff engine) — never mid-aggregation — so a control verb
//! is always observed at a consistent point in the round state machine.
//!
//! Verb grammar (one command per request body, whitespace-separated):
//!
//! ```text
//! drain                    # finish the in-flight round, then stop cleanly
//! quiesce                  # pause at the next boundary (clients stay connected)
//! resume                   # leave quiesce
//! set-planner <spec>       # e.g. set-planner tiered:4   (PlannerKind grammar)
//! set-strategy <spec>      # e.g. set-strategy fedprox:0.1 (Aggregation grammar)
//! status                   # read-only: current state line, nothing enqueued
//! ```
//!
//! `set-planner` / `set-strategy` specs reuse the exact name-keyed
//! registries the CLI uses ([`crate::orchestrator::planner::planner_by_name`],
//! [`crate::orchestrator::strategy::registry::strategy_by_name`]), so an
//! operator can only install something `fedhpc list` advertises.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// The verbs an operator can issue (label values for
/// `fedhpc_control_commands_total{verb=...}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verb {
    Drain,
    Quiesce,
    Resume,
    SetPlanner,
    SetStrategy,
    Status,
}

impl Verb {
    /// Every verb, in exposition/label order.
    pub const ALL: &'static [Verb] = &[
        Verb::Drain,
        Verb::Quiesce,
        Verb::Resume,
        Verb::SetPlanner,
        Verb::SetStrategy,
        Verb::Status,
    ];

    /// The wire spelling (also the metric label value).
    pub fn name(self) -> &'static str {
        match self {
            Verb::Drain => "drain",
            Verb::Quiesce => "quiesce",
            Verb::Resume => "resume",
            Verb::SetPlanner => "set-planner",
            Verb::SetStrategy => "set-strategy",
            Verb::Status => "status",
        }
    }
}

/// A validated operator command. `Status` is answered directly by the
/// HTTP layer and never enqueued; everything else waits in the mailbox
/// for the next round/commit boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ControlCmd {
    Drain,
    Quiesce,
    Resume,
    /// Planner spec, already validated against the planner registry.
    SetPlanner(String),
    /// Strategy spec, already validated against the strategy registry.
    SetStrategy(String),
    Status,
}

impl ControlCmd {
    pub fn verb(&self) -> Verb {
        match self {
            ControlCmd::Drain => Verb::Drain,
            ControlCmd::Quiesce => Verb::Quiesce,
            ControlCmd::Resume => Verb::Resume,
            ControlCmd::SetPlanner(_) => Verb::SetPlanner,
            ControlCmd::SetStrategy(_) => Verb::SetStrategy,
            ControlCmd::Status => Verb::Status,
        }
    }
}

/// Parse + validate one operator command line. Spec arguments are
/// checked against the name-keyed registries here, so an accepted
/// command can always be applied at the boundary.
pub fn parse_verb(line: &str) -> Result<ControlCmd> {
    let mut words = line.split_whitespace();
    let verb = words.next().ok_or_else(|| anyhow!("empty command"))?;
    let arg = words.next();
    if let Some(extra) = words.next() {
        return Err(anyhow!("unexpected trailing token {extra:?}"));
    }
    let no_arg = |cmd: ControlCmd| match arg {
        None => Ok(cmd),
        Some(a) => Err(anyhow!("verb {verb:?} takes no argument, got {a:?}")),
    };
    match verb {
        "drain" => no_arg(ControlCmd::Drain),
        "quiesce" => no_arg(ControlCmd::Quiesce),
        "resume" => no_arg(ControlCmd::Resume),
        "status" => no_arg(ControlCmd::Status),
        "set-planner" => {
            let spec = arg.ok_or_else(|| anyhow!("set-planner requires a spec argument"))?;
            // Validate eagerly: unknown/ill-formed specs never enter
            // the mailbox.
            crate::orchestrator::planner::planner_by_name(spec)
                .map_err(|e| anyhow!("invalid planner spec {spec:?}: {e}"))?;
            Ok(ControlCmd::SetPlanner(spec.to_string()))
        }
        "set-strategy" => {
            let spec = arg.ok_or_else(|| anyhow!("set-strategy requires a spec argument"))?;
            crate::orchestrator::strategy::registry::strategy_by_name(spec)
                .map_err(|e| anyhow!("invalid strategy spec {spec:?}: {e}"))?;
            Ok(ControlCmd::SetStrategy(spec.to_string()))
        }
        other => Err(anyhow!(
            "unknown verb {other:?} (expected one of drain, quiesce, resume, \
             set-planner, set-strategy, status)"
        )),
    }
}

/// Shared state between the HTTP responder (producer) and the
/// orchestrator (consumer). All methods are cheap and lock-scoped;
/// nothing here is on the per-update hot path.
#[derive(Default)]
pub struct ControlPlane {
    mailbox: Mutex<VecDeque<ControlCmd>>,
    ready: AtomicBool,
    /// Last state line published by the orchestrator at a boundary.
    status: Mutex<String>,
    /// Role + upstream of this node in the aggregation tree, set once
    /// at startup. `None` (the default) means a flat root server, which
    /// keeps the status line byte-identical to pre-hierarchy builds.
    identity: Mutex<Option<NodeIdentity>>,
}

#[derive(Clone, Debug)]
struct NodeIdentity {
    role: String,
    upstream: Option<String>,
}

impl ControlPlane {
    pub fn new() -> Self {
        ControlPlane {
            mailbox: Mutex::new(VecDeque::new()),
            ready: AtomicBool::new(false),
            status: Mutex::new("state=starting".to_string()),
            identity: Mutex::new(None),
        }
    }

    /// Enqueue a validated command for the next boundary.
    pub fn submit(&self, cmd: ControlCmd) {
        crate::util::lock_unpoisoned(&self.mailbox).push_back(cmd);
    }

    /// Take every queued command, FIFO. Called by the orchestrator at
    /// round/commit boundaries (and while parked in quiesce).
    pub fn drain_mailbox(&self) -> Vec<ControlCmd> {
        crate::util::lock_unpoisoned(&self.mailbox).drain(..).collect()
    }

    /// `/readyz` flips true once the server is listening *and* the
    /// first round/plan has been dispatched.
    pub fn mark_ready(&self) {
        self.ready.store(true, Ordering::Release);
    }

    pub fn is_ready(&self) -> bool {
        self.ready.load(Ordering::Acquire)
    }

    /// Publish the operator-visible state line (shown by `status` and
    /// `GET /status`).
    pub fn set_status(&self, line: String) {
        *crate::util::lock_unpoisoned(&self.status) = line;
    }

    pub fn status_line(&self) -> String {
        let mut line = crate::util::lock_unpoisoned(&self.status).clone();
        if let Some(id) = crate::util::lock_unpoisoned(&self.identity).as_ref() {
            line.push_str(" role=");
            line.push_str(&id.role);
            if let Some(up) = &id.upstream {
                line.push_str(" upstream=");
                line.push_str(up);
            }
        }
        line
    }

    /// Declare this node's place in the aggregation tree. Called once
    /// at startup by the launcher/CLI; `role` shows on `/status` and
    /// `"aggregator"` additionally gates the mutating registry verbs
    /// (`set-planner` / `set-strategy`), which only make sense on the
    /// root where the cohort planner and strategy actually live.
    pub fn set_identity(&self, role: &str, upstream: Option<&str>) {
        *crate::util::lock_unpoisoned(&self.identity) = Some(NodeIdentity {
            role: role.to_string(),
            upstream: upstream.map(str::to_string),
        });
    }

    /// True when [`ControlPlane::set_identity`] declared this node a
    /// mid-tier aggregator (the HTTP layer answers `409` to
    /// `set-planner` / `set-strategy` in that case).
    pub fn is_aggregator(&self) -> bool {
        crate::util::lock_unpoisoned(&self.identity)
            .as_ref()
            .is_some_and(|id| id.role == "aggregator")
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    #[test]
    fn bare_verbs_parse() {
        assert_eq!(parse_verb("drain").unwrap(), ControlCmd::Drain);
        assert_eq!(parse_verb("  quiesce ").unwrap(), ControlCmd::Quiesce);
        assert_eq!(parse_verb("resume").unwrap(), ControlCmd::Resume);
        assert_eq!(parse_verb("status").unwrap(), ControlCmd::Status);
    }

    #[test]
    fn bare_verbs_reject_arguments() {
        assert!(parse_verb("drain now").is_err());
        assert!(parse_verb("status please").is_err());
    }

    #[test]
    fn set_planner_validates_against_registry() {
        let cmd = parse_verb("set-planner tiered:4").unwrap();
        assert_eq!(cmd, ControlCmd::SetPlanner("tiered:4".to_string()));
        assert_eq!(cmd.verb().name(), "set-planner");
        assert!(parse_verb("set-planner no-such-planner").is_err());
        assert!(parse_verb("set-planner").is_err());
    }

    #[test]
    fn set_strategy_validates_against_registry() {
        let cmd = parse_verb("set-strategy fedprox:0.1").unwrap();
        assert_eq!(cmd, ControlCmd::SetStrategy("fedprox:0.1".to_string()));
        assert!(parse_verb("set-strategy bogus").is_err());
        assert!(parse_verb("set-strategy").is_err());
    }

    #[test]
    fn unknown_and_empty_verbs_error() {
        assert!(parse_verb("").is_err());
        assert!(parse_verb("explode").is_err());
    }

    #[test]
    fn mailbox_is_fifo_and_drains() {
        let cp = ControlPlane::new();
        assert!(cp.drain_mailbox().is_empty());
        cp.submit(ControlCmd::Quiesce);
        cp.submit(ControlCmd::Resume);
        assert_eq!(
            cp.drain_mailbox(),
            vec![ControlCmd::Quiesce, ControlCmd::Resume]
        );
        assert!(cp.drain_mailbox().is_empty());
    }

    #[test]
    fn ready_and_status() {
        let cp = ControlPlane::new();
        assert!(!cp.is_ready());
        cp.mark_ready();
        assert!(cp.is_ready());
        assert_eq!(cp.status_line(), "state=starting");
        cp.set_status("state=running round=3".to_string());
        assert_eq!(cp.status_line(), "state=running round=3");
    }

    #[test]
    fn identity_extends_status_and_gates_aggregators() {
        let cp = ControlPlane::new();
        // default: no identity, no suffix, not an aggregator
        assert!(!cp.is_aggregator());
        assert_eq!(cp.status_line(), "state=starting");
        // a root server advertises its role but stays mutable
        cp.set_identity("server", None);
        assert!(!cp.is_aggregator());
        assert_eq!(cp.status_line(), "state=starting role=server");
        // a mid-tier aggregator advertises role + upstream and is gated
        cp.set_identity("aggregator", Some("10.0.0.1:7070"));
        assert!(cp.is_aggregator());
        cp.set_status("state=running round=2".to_string());
        assert_eq!(
            cp.status_line(),
            "state=running round=2 role=aggregator upstream=10.0.0.1:7070"
        );
    }

    #[test]
    fn every_verb_has_a_stable_name() {
        let names: Vec<_> = Verb::ALL.iter().map(|v| v.name()).collect();
        assert_eq!(
            names,
            vec![
                "drain",
                "quiesce",
                "resume",
                "set-planner",
                "set-strategy",
                "status"
            ]
        );
    }
}
