//! Hand-rolled HTTP/1.1 responder for metrics exposition and operator
//! control. std-only (`TcpListener` + threads): the dependency posture
//! stays anyhow + log, and the surface is deliberately tiny — five
//! routes, `Connection: close`, no keep-alive, no chunking.
//!
//! Routes:
//!
//! | route            | method | reply                                          |
//! |------------------|--------|------------------------------------------------|
//! | `/metrics`       | GET    | Prometheus text exposition 0.0.4               |
//! | `/healthz`       | GET    | `200 ok` while the process is alive            |
//! | `/readyz`        | GET    | `200 ready` after the first round dispatched, `503` before |
//! | `/status`        | GET    | current orchestrator state line                |
//! | `/control`       | POST   | body = one verb line (see [`super::control`])  |
//!
//! This port parses network input, so the whole module is in the
//! fedhpc-lint panic-safety scope: malformed requests produce error
//! responses, never panics.

use super::control::{parse_verb, ControlCmd, ControlPlane};
use super::registry::{names, Registry};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Upper bound on a request (request line + headers + body). The
/// largest legitimate request is a short control verb; anything bigger
/// is garbage and gets `400`.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// How often the accept loop checks the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Per-connection socket timeout: an operator port must never let a
/// stalled peer pin a thread.
const IO_TIMEOUT: Duration = Duration::from_millis(2000);

/// The exposition + control listener. Binding spawns one accept
/// thread; each connection is answered on a short-lived handler
/// thread and closed. Dropping the server (or calling
/// [`TelemetryServer::shutdown`]) stops the accept loop.
pub struct TelemetryServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TelemetryServer {
    /// Bind `addr` (e.g. `"127.0.0.1:9090"`, port 0 for ephemeral) and
    /// start serving `registry` / `control`.
    pub fn bind(addr: &str, registry: Arc<Registry>, control: Arc<ControlPlane>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("telemetry bind {addr}"))?;
        let local_addr = listener
            .local_addr()
            .context("telemetry local_addr")?;
        listener
            .set_nonblocking(true)
            .context("telemetry set_nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("telemetry-http".to_string())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let reg = registry.clone();
                            let cp = control.clone();
                            let spawned = std::thread::Builder::new()
                                .name("telemetry-conn".to_string())
                                .spawn(move || handle_conn(stream, &reg, &cp));
                            if let Err(e) = spawned {
                                log::warn!("telemetry: handler spawn failed: {e}");
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(e) => {
                            log::warn!("telemetry: accept error: {e}");
                            std::thread::sleep(ACCEPT_POLL);
                        }
                    }
                }
            })
            .context("telemetry accept thread spawn")?;
        log::info!("telemetry: serving /metrics on {local_addr}");
        Ok(TelemetryServer {
            local_addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop the accept loop and join the accept thread. In-flight
    /// connection handlers finish on their own (they are bounded by
    /// [`IO_TIMEOUT`]).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            if h.join().is_err() {
                log::warn!("telemetry: accept thread panicked");
            }
        }
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// One request → one response → close. All parse failures answer 400.
fn handle_conn(mut stream: TcpStream, registry: &Registry, control: &ControlPlane) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let response = match read_request(&mut stream) {
        Ok(req) => route(&req, registry, control),
        Err(e) => Response::text(400, "Bad Request", &format!("bad request: {e}\n")),
    };
    if let Err(e) = response.write_to(&mut stream) {
        log::debug!("telemetry: response write failed: {e}");
    }
}

struct Request {
    method: String,
    path: String,
    body: String,
}

/// Read one HTTP/1.1 request (headers + optional body) off the stream.
/// Size-capped, timeout-bounded, index-free.
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    let header_end = loop {
        if let Some(pos) = find_header_end(&buf) {
            break pos;
        }
        if buf.len() >= MAX_REQUEST_BYTES {
            anyhow::bail!("request exceeds {MAX_REQUEST_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("read")?;
        if n == 0 {
            anyhow::bail!("connection closed mid-request");
        }
        buf.extend_from_slice(chunk.get(..n).unwrap_or(&[]));
    };
    let head = buf.get(..header_end).unwrap_or(&[]);
    let head = String::from_utf8_lossy(head).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        anyhow::bail!("malformed request line {request_line:?}");
    }
    let content_length = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    if content_length > MAX_REQUEST_BYTES {
        anyhow::bail!("content-length {content_length} exceeds cap");
    }
    let body_start = header_end + 4; // past \r\n\r\n
    let mut body: Vec<u8> = buf.get(body_start..).unwrap_or(&[]).to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("read body")?;
        if n == 0 {
            break;
        }
        body.extend_from_slice(chunk.get(..n).unwrap_or(&[]));
    }
    body.truncate(content_length);
    Ok(Request {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Byte offset of the first `\r\n\r\n`, if complete headers arrived.
fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn route(req: &Request, registry: &Registry, control: &ControlPlane) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => Response {
            code: 200,
            reason: "OK",
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: registry.render(),
        },
        ("GET", "/healthz") => Response::text(200, "OK", "ok\n"),
        ("GET", "/readyz") => {
            if control.is_ready() {
                Response::text(200, "OK", "ready\n")
            } else {
                Response::text(503, "Service Unavailable", "starting\n")
            }
        }
        ("GET", "/status") => {
            let mut line = control.status_line();
            line.push('\n');
            Response::text(200, "OK", &line)
        }
        ("POST", "/control") => handle_control(req.body.trim(), registry, control),
        ("GET", "/") => Response::text(
            200,
            "OK",
            "fedhpc telemetry: /metrics /healthz /readyz /status, POST /control\n",
        ),
        _ => Response::text(404, "Not Found", "not found\n"),
    }
}

fn handle_control(body: &str, registry: &Registry, control: &ControlPlane) -> Response {
    let cmd = match parse_verb(body) {
        Ok(cmd) => cmd,
        Err(e) => return Response::text(400, "Bad Request", &format!("rejected: {e}\n")),
    };
    // Mid-tier aggregators have no planner or strategy of their own —
    // both live on the root — so the mutating registry verbs are
    // refused with 409 rather than silently accepted and dropped.
    if control.is_aggregator()
        && matches!(cmd, ControlCmd::SetPlanner(_) | ControlCmd::SetStrategy(_))
    {
        return Response::text(
            409,
            "Conflict",
            &format!(
                "refused: {} is not valid on an aggregator-role node (issue it to the root)\n",
                cmd.verb().name()
            ),
        );
    }
    registry
        .counter_with(
            names::CONTROL_COMMANDS_TOTAL,
            "Operator control verbs accepted, by verb.",
            "verb",
            cmd.verb().name(),
        )
        .inc();
    match cmd {
        ControlCmd::Status => {
            let mut line = control.status_line();
            line.push('\n');
            Response::text(200, "OK", &line)
        }
        other => {
            let verb = other.verb().name();
            control.submit(other);
            Response::text(202, "Accepted", &format!("accepted: {verb}\n"))
        }
    }
}

struct Response {
    code: u16,
    reason: &'static str,
    content_type: &'static str,
    body: String,
}

impl Response {
    fn text(code: u16, reason: &'static str, body: &str) -> Self {
        Response {
            code,
            reason,
            content_type: "text/plain; charset=utf-8",
            body: body.to_string(),
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.code,
            self.reason,
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes()).context("write head")?;
        stream
            .write_all(self.body.as_bytes())
            .context("write body")?;
        stream.flush().context("flush")?;
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    #[test]
    fn header_end_detection() {
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n\r\n"), Some(16));
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n"), None);
        assert_eq!(find_header_end(b""), None);
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            body: body.to_string(),
        }
    }

    #[test]
    fn routes_respond() {
        let reg = Registry::new();
        reg.counter("t_total", "t").inc();
        let cp = ControlPlane::new();
        let r = route(&req("GET", "/metrics", ""), &reg, &cp);
        assert_eq!(r.code, 200);
        assert!(r.content_type.contains("version=0.0.4"));
        assert!(r.body.contains("t_total 1"));
        assert_eq!(route(&req("GET", "/healthz", ""), &reg, &cp).code, 200);
        assert_eq!(route(&req("GET", "/readyz", ""), &reg, &cp).code, 503);
        cp.mark_ready();
        assert_eq!(route(&req("GET", "/readyz", ""), &reg, &cp).code, 200);
        assert_eq!(route(&req("GET", "/nope", ""), &reg, &cp).code, 404);
        assert_eq!(route(&req("PUT", "/metrics", ""), &reg, &cp).code, 404);
    }

    #[test]
    fn control_route_enqueues_and_counts() {
        let reg = Registry::new();
        let cp = ControlPlane::new();
        let r = route(&req("POST", "/control", "quiesce"), &reg, &cp);
        assert_eq!(r.code, 202);
        assert_eq!(cp.drain_mailbox(), vec![ControlCmd::Quiesce]);
        // status answers inline, enqueues nothing
        let r = route(&req("POST", "/control", "status"), &reg, &cp);
        assert_eq!(r.code, 200);
        assert!(cp.drain_mailbox().is_empty());
        // bad spec rejected before the mailbox
        let r = route(&req("POST", "/control", "set-planner bogus"), &reg, &cp);
        assert_eq!(r.code, 400);
        assert!(cp.drain_mailbox().is_empty());
        let text = reg.render();
        assert!(text.contains("fedhpc_control_commands_total{verb=\"quiesce\"} 1"));
        assert!(text.contains("fedhpc_control_commands_total{verb=\"status\"} 1"));
    }

    #[test]
    fn aggregator_role_refuses_registry_verbs_with_409() {
        let reg = Registry::new();
        let cp = ControlPlane::new();
        cp.set_identity("aggregator", Some("127.0.0.1:7070"));
        for verb in ["set-planner tiered:4", "set-strategy fedprox:0.1"] {
            let r = route(&req("POST", "/control", verb), &reg, &cp);
            assert_eq!(r.code, 409);
            assert!(r.body.contains("aggregator-role"));
            assert!(cp.drain_mailbox().is_empty());
        }
        // refused verbs are not counted as accepted
        assert!(!reg.render().contains("verb=\"set-planner\""));
        // lifecycle verbs still flow (an operator can drain a site)
        let r = route(&req("POST", "/control", "quiesce"), &reg, &cp);
        assert_eq!(r.code, 202);
        assert_eq!(cp.drain_mailbox(), vec![ControlCmd::Quiesce]);
        // /status carries the tree identity
        let r = route(&req("GET", "/status", ""), &reg, &cp);
        assert!(r.body.contains("role=aggregator upstream=127.0.0.1:7070"));
    }
}
