//! Metric registry: atomic counters/gauges + fixed-bucket histograms,
//! BTreeMap-ordered so the `/metrics` exposition is byte-stable for a
//! given set of values (golden-file tested).
//!
//! Hot-path cost: one relaxed `AtomicU64` op per event — see the module
//! docs on [`crate::telemetry`] for the exact accuracy contract, and
//! `rust/benches/telemetry.rs` for the measured overhead on the ingest
//! path (<1% of round time is the acceptance bar).
//!
//! Handles ([`Arc<Counter>`] etc.) are resolved once — at construction
//! of the instrumented object or behind a `OnceLock` — so the registry
//! mutex is never on a per-event path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Canonical metric names — the single inventory shared by the
/// instrumentation sites, the README "Operations" table and the tests.
pub mod names {
    /// Rounds (sync) / commits (async) finalized, including empty ones.
    pub const ROUNDS_TOTAL: &str = "fedhpc_rounds_total";
    /// Wall-clock (or virtual, in sims) seconds per round/commit.
    pub const ROUND_SECONDS: &str = "fedhpc_round_duration_seconds";
    /// Per-folded-update staleness in commits (always 0 in sync mode).
    pub const STALENESS: &str = "fedhpc_update_staleness";
    /// Updates discarded for exceeding `max_staleness`.
    pub const STALE_DROPS_TOTAL: &str = "fedhpc_stale_drops_total";
    /// Deadline misses, labelled by client speed tier ([`super::tier_of`]).
    pub const DEADLINE_MISSES_TOTAL: &str = "fedhpc_deadline_misses_total";
    /// Encoded update bytes folded by the server (ingest volume;
    /// divide by time for throughput).
    pub const INGEST_BYTES_TOTAL: &str = "fedhpc_ingest_bytes_total";
    /// Updates folded by the server.
    pub const INGEST_UPDATES_TOTAL: &str = "fedhpc_ingest_updates_total";
    /// Fold jobs queued in the sharded-ingest pool (0 when serial).
    pub const INGEST_SHARD_QUEUE_DEPTH: &str = "fedhpc_ingest_shard_queue_depth";
    /// Ingest producer stalls on a full shard queue (backpressure).
    pub const INGEST_STALLS_TOTAL: &str = "fedhpc_ingest_stalls_total";
    /// Nanoseconds shard workers spent inside fold jobs.
    pub const INGEST_FOLD_NS_TOTAL: &str = "fedhpc_ingest_fold_ns_total";
    /// ScratchPool takes served from the free-list.
    pub const SCRATCH_HITS_TOTAL: &str = "fedhpc_scratch_hits_total";
    /// ScratchPool takes that had to allocate.
    pub const SCRATCH_MISSES_TOTAL: &str = "fedhpc_scratch_misses_total";
    /// TCP connections accepted since process start.
    pub const TCP_ACCEPTS_TOTAL: &str = "fedhpc_tcp_accepts_total";
    /// Registered TCP peers currently connected.
    pub const TCP_ACTIVE_CONNECTIONS: &str = "fedhpc_tcp_active_connections";
    /// Frames queued in per-peer TCP outboxes (backpressure depth).
    pub const TCP_OUTBOX_FRAMES: &str = "fedhpc_tcp_outbox_frames";
    /// Reactor sweep-thread wakeups (park/unpark churn).
    pub const TCP_REACTOR_WAKEUPS_TOTAL: &str = "fedhpc_tcp_reactor_wakeups_total";
    /// Server→client payload bytes before frame compression.
    pub const TCP_TX_RAW_BYTES_TOTAL: &str = "fedhpc_tcp_tx_raw_bytes_total";
    /// Server→client bytes actually written to sockets (post-compression,
    /// frame headers included).
    pub const TCP_TX_WIRE_BYTES_TOTAL: &str = "fedhpc_tcp_tx_wire_bytes_total";
    /// Client→server bytes read off sockets (frame headers included).
    pub const TCP_RX_WIRE_BYTES_TOTAL: &str = "fedhpc_tcp_rx_wire_bytes_total";
    /// Current global model version (commits applied).
    pub const MODEL_VERSION: &str = "fedhpc_model_version";
    /// Cohorts planned since process start.
    pub const COHORTS_PLANNED_TOTAL: &str = "fedhpc_cohorts_planned_total";
    /// Size of the most recently planned cohort.
    pub const COHORT_SIZE: &str = "fedhpc_cohort_size";
    /// Operator control verbs accepted, labelled by verb.
    pub const CONTROL_COMMANDS_TOTAL: &str = "fedhpc_control_commands_total";
    /// Member updates folded by a site aggregator, labelled by site.
    pub const SITE_UPDATES_TOTAL: &str = "fedhpc_site_updates_total";
    /// Nanoseconds a site aggregator spent folding, labelled by site.
    pub const SITE_FOLD_NS_TOTAL: &str = "fedhpc_site_fold_ns_total";
    /// Encoded bytes of pre-folded deltas reported upstream, labelled
    /// by site.
    pub const UPSTREAM_REPORT_BYTES_TOTAL: &str = "fedhpc_upstream_report_bytes_total";
}

/// Round/commit latency buckets, seconds.
pub const ROUND_SECONDS_BUCKETS: &[f64] =
    &[0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0];

/// Update staleness buckets, commits behind.
pub const STALENESS_BUCKETS: &[f64] = &[0.0, 1.0, 2.0, 3.0, 5.0, 8.0, 13.0, 21.0, 34.0];

/// Client speed tier for per-tier metric labels, derived from the
/// registered profile's `speed_factor` (1.0 = the reference node).
pub fn tier_of(speed_factor: f64) -> &'static str {
    if speed_factor >= 0.9 {
        "fast"
    } else if speed_factor >= 0.45 {
        "mid"
    } else {
        "slow"
    }
}

/// Monotonically increasing event count. All ops relaxed.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (may go down). All ops relaxed; `dec` saturates
/// at zero so a spurious extra decrement can never wrap to 2^64-1.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram. Buckets are stored non-cumulative and
/// accumulated at exposition; the sum is kept in integer microunits
/// (1e-6 of the observed value) so it stays a relaxed `fetch_add`.
#[derive(Debug)]
pub struct Histogram {
    /// Ascending inclusive upper bounds; an implicit +Inf bucket
    /// follows the last.
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_micro: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    /// Record one observation. Negative / non-finite values clamp to 0
    /// for the sum (the count and bucket still move, so nothing is
    /// silently lost).
    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        let micro = if v.is_finite() && v > 0.0 {
            (v * 1e6).round() as u64
        } else {
            0
        };
        self.sum_micro.fetch_add(micro, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Observed sum (reconstructed from microunits).
    pub fn sum(&self) -> f64 {
        self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Non-cumulative bucket counts (one extra +Inf bucket at the end).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist(_) => "histogram",
        }
    }
}

struct Family {
    help: String,
    /// Label suffix (`""` or `{k="v"}`) → series. BTreeMap keeps the
    /// exposition order stable.
    series: BTreeMap<String, Metric>,
}

/// A set of metric families. `Registry::default()`/`new()` builds an
/// empty private instance (tests, embedders); production
/// instrumentation shares [`global()`].
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

/// The process-wide registry every always-on instrumentation site
/// records into. Returned as an `Arc` so the exposition server can
/// hold the same handle it would hold for a private test registry.
pub fn global() -> &'static Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Registry::default()))
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn get_or_insert<M, F, G>(&self, name: &str, series: &str, help: &str, make: F, pick: G) -> M
    where
        M: Clone,
        F: FnOnce() -> (M, Metric),
        G: Fn(&Metric) -> Option<M>,
    {
        let mut fams = crate::util::lock_unpoisoned(&self.families);
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            series: BTreeMap::new(),
        });
        if let Some(existing) = fam.series.get(series) {
            if let Some(m) = pick(existing) {
                return m;
            }
            // Kind clash: never panic on a telemetry path — hand back a
            // detached instance so the caller still works, and say so.
            log::warn!(
                "telemetry: {name}{series} re-registered as a different kind \
                 (was {}); returning a detached metric",
                existing.kind()
            );
            return make().0;
        }
        let (handle, metric) = make();
        fam.series.insert(series.to_string(), metric);
        handle
    }

    /// Get or register the counter `name` (no labels).
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, "", "")
    }

    /// Get or register the counter `name{label="value"}`. An empty
    /// `label` means no labels.
    pub fn counter_with(&self, name: &str, help: &str, label: &str, value: &str) -> Arc<Counter> {
        let series = series_suffix(label, value);
        self.get_or_insert(
            name,
            &series,
            help,
            || {
                let c = Arc::new(Counter::default());
                (c.clone(), Metric::Counter(c))
            },
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.get_or_insert(
            name,
            "",
            help,
            || {
                let g = Arc::new(Gauge::default());
                (g.clone(), Metric::Gauge(g))
            },
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    /// Get or register the histogram `name` over `bounds` (ascending
    /// upper bounds; +Inf is implicit). Bounds are fixed at first
    /// registration.
    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Arc<Histogram> {
        self.get_or_insert(
            name,
            "",
            help,
            || {
                let h = Arc::new(Histogram::new(bounds));
                (h.clone(), Metric::Hist(h))
            },
            |m| match m {
                Metric::Hist(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Render the whole registry in Prometheus text exposition format
    /// 0.0.4. Family and series order is BTreeMap (byte-stable).
    pub fn render(&self) -> String {
        let fams = crate::util::lock_unpoisoned(&self.families);
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            let kind = fam
                .series
                .values()
                .next()
                .map(Metric::kind)
                .unwrap_or("untyped");
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for (suffix, metric) in fam.series.iter() {
                match metric {
                    Metric::Counter(c) => {
                        out.push_str(&format!("{name}{suffix} {}\n", c.get()));
                    }
                    Metric::Gauge(g) => {
                        out.push_str(&format!("{name}{suffix} {}\n", g.get()));
                    }
                    Metric::Hist(h) => {
                        let counts = h.bucket_counts();
                        let mut cum = 0u64;
                        for (bound, n) in h.bounds.iter().zip(counts.iter()) {
                            cum += n;
                            out.push_str(&format!(
                                "{name}_bucket{{le=\"{}\"}} {cum}\n",
                                fmt_f64(*bound)
                            ));
                        }
                        cum += counts.last().copied().unwrap_or(0);
                        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                        out.push_str(&format!("{name}_sum {}\n", fmt_f64(h.sum())));
                        out.push_str(&format!("{name}_count {}\n", h.count()));
                    }
                }
            }
        }
        out
    }
}

fn series_suffix(label: &str, value: &str) -> String {
    if label.is_empty() {
        String::new()
    } else {
        format!("{{{label}=\"{value}\"}}")
    }
}

/// Stable float formatting for exposition: integral values print
/// without a fractional part (`1`, not `1.0`), everything else uses
/// Rust's shortest-roundtrip default.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("t_total", "a counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name → same underlying series
        let c2 = reg.counter("t_total", "a counter");
        c2.inc();
        assert_eq!(c.get(), 6);
        let g = reg.gauge("t_gauge", "a gauge");
        g.set(9);
        g.inc();
        g.dec();
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn gauge_dec_saturates_at_zero() {
        let g = Gauge::default();
        g.dec();
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let reg = Registry::new();
        let h = reg.histogram("t_lat", "latency", &[1.0, 2.0]);
        for v in [0.5, 1.5, 3.0, 2.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.bucket_counts(), vec![1, 2, 1]);
        assert!((h.sum() - 7.0).abs() < 1e-9);
        // negative / non-finite observations count but add 0 to sum
        h.observe(-4.0);
        h.observe(f64::NAN);
        assert_eq!(h.count(), 6);
        assert!((h.sum() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn labelled_series_expose_separately() {
        let reg = Registry::new();
        reg.counter_with("t_miss_total", "misses", "tier", "slow").add(2);
        reg.counter_with("t_miss_total", "misses", "tier", "fast").inc();
        let text = reg.render();
        assert!(text.contains("t_miss_total{tier=\"fast\"} 1"));
        assert!(text.contains("t_miss_total{tier=\"slow\"} 2"));
        // one HELP/TYPE pair for the family
        assert_eq!(text.matches("# TYPE t_miss_total").count(), 1);
    }

    #[test]
    fn kind_clash_returns_detached_metric_not_panic() {
        let reg = Registry::new();
        let c = reg.counter("t_clash", "first");
        c.inc();
        let g = reg.gauge("t_clash", "second");
        g.set(99);
        // the registered series is untouched by the detached handle
        assert!(reg.render().contains("t_clash 1"));
    }

    #[test]
    fn render_order_is_stable() {
        let reg = Registry::new();
        reg.counter("t_b_total", "b").inc();
        reg.counter("t_a_total", "a").inc();
        let a = reg.render();
        let b = reg.render();
        assert_eq!(a, b);
        let pos_a = a.find("t_a_total").unwrap();
        let pos_b = a.find("t_b_total").unwrap();
        assert!(pos_a < pos_b, "families must render name-ordered");
    }

    #[test]
    fn tier_boundaries() {
        assert_eq!(tier_of(1.0), "fast");
        assert_eq!(tier_of(0.6), "mid");
        assert_eq!(tier_of(0.2), "slow");
    }

    #[test]
    fn fmt_f64_stable() {
        assert_eq!(fmt_f64(1.0), "1");
        assert_eq!(fmt_f64(0.25), "0.25");
        assert_eq!(fmt_f64(0.0), "0");
    }
}
