//! Wire protocol: every orchestrator↔client message, with a compact
//! hand-rolled binary codec (DESIGN.md §6).
//!
//! Layout: `[version u8][tag u8][body …]`, little-endian, length-
//! prefixed slices. The codec is exercised by both transports and by
//! round-trip + fuzz-ish tests below.
//!
//! # Protocol versions
//!
//! * **v1** — the original layout.
//! * **v2** — [`Msg::Update`] additionally carries `base_version`, the
//!   model version the client trained on (right after `client`). The
//!   buffered-async round engine needs it to compute an update's
//!   staleness; the synchronous engine ignores it. The decoder still
//!   accepts v1 frames (every other message is layout-identical, and a
//!   v1 `Update` defaults `base_version` to its round tag — exactly
//!   what a synchronous client would have sent).
//! * **v3** — message layout identical to v2. The bump is a
//!   *capability signal* for the TCP frame layer: a peer whose frames
//!   carry version ≥ 3 ([`FRAME_COMPRESSION_VERSION`]) understands the
//!   compressed-frame flag in `network::framing`, so the other side may
//!   start sending compressed frames to it. v1/v2 peers keep receiving
//!   plain frames — interop is preserved without any handshake message.
//!   Encoders always emit v3.

use crate::cluster::NodeId;
use crate::compress::{DecodedView, Encoded, PreEncoded, QData, Quantized, Sparse};
use crate::config::CompressionConfig;
use crate::util::bytes::{Reader, Writer};
use anyhow::{bail, Result};
use std::sync::Arc;

pub const PROTOCOL_VERSION: u8 = 3;

/// Oldest protocol version the decoder still accepts (see the module
/// docs for the per-version differences).
pub const MIN_PROTOCOL_VERSION: u8 = 1;

/// Peers emitting this protocol version (or newer) decode the
/// compressed-frame flag (`network::framing::COMPRESSED_FLAG`). The
/// transport inspects the version byte of a peer's frames — byte 0 of
/// every encoded message — and only compresses toward peers that have
/// proven it.
pub const FRAME_COMPRESSION_VERSION: u8 = 3;

/// What a client reports about itself at registration / profiling
/// (paper §4.1 resource profiling).
#[derive(Debug, Clone, PartialEq)]
pub struct ClientProfile {
    /// Relative compute speed from the local benchmark (higher=faster).
    pub speed_factor: f64,
    pub mem_gb: f64,
    /// Link bandwidth estimate, bytes/sec.
    pub link_bw: f64,
    /// Local dataset size (examples).
    pub n_samples: u64,
    /// Measured per-step latency from the profiling benchmark (ms).
    pub bench_step_ms: f64,
}

/// Per-update training statistics (drives weighted aggregation).
#[derive(Debug, Clone, PartialEq)]
pub struct UpdateStats {
    pub n_samples: u64,
    pub train_loss: f32,
    pub steps: u32,
    pub compute_ms: f64,
    /// Variance of the update entries (for inverse-variance weighting).
    pub update_var: f32,
}

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// client → server: join the federation.
    Register {
        client: NodeId,
        profile: ClientProfile,
    },
    /// server → client: registration accepted.
    RegisterAck { client: NodeId },
    /// server → client: start round `round` with this global model.
    RoundStart {
        round: u32,
        model_version: u32,
        deadline_ms: u64,
        lr: f32,
        mu: f32,
        local_epochs: u32,
        /// Global model parameters (dense or compressed broadcast).
        params: Encoded,
        /// Seed for the federated-dropout mask this client must use.
        mask_seed: u64,
        compression: CompressionConfig,
    },
    /// client → server: local update Δ for `round`.
    Update {
        round: u32,
        client: NodeId,
        /// Model version the client trained on (the `model_version` of
        /// the `RoundStart` it answers). The async engine derives the
        /// update's staleness from it; in sync mode it equals `round`.
        base_version: u32,
        delta: Encoded,
        stats: UpdateStats,
    },
    /// client → server: still alive mid-round.
    Heartbeat { client: NodeId, round: u32 },
    /// server → client: round result notification (for logging).
    RoundEnd { round: u32, model_version: u32 },
    /// either direction: abort current round.
    Abort { round: u32 },
    /// server → client: terminate.
    Shutdown,
}

impl Msg {
    pub fn tag(&self) -> u8 {
        match self {
            Msg::Register { .. } => 1,
            Msg::RegisterAck { .. } => 2,
            Msg::RoundStart { .. } => 3,
            Msg::Update { .. } => 4,
            Msg::Heartbeat { .. } => 5,
            Msg::RoundEnd { .. } => 6,
            Msg::Abort { .. } => 7,
            Msg::Shutdown => 8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Msg::Register { .. } => "Register",
            Msg::RegisterAck { .. } => "RegisterAck",
            Msg::RoundStart { .. } => "RoundStart",
            Msg::Update { .. } => "Update",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::RoundEnd { .. } => "RoundEnd",
            Msg::Abort { .. } => "Abort",
            Msg::Shutdown => "Shutdown",
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        w.u8(PROTOCOL_VERSION);
        w.u8(self.tag());
        match self {
            Msg::Register { client, profile } => {
                w.u32(*client);
                encode_profile(&mut w, profile);
            }
            Msg::RegisterAck { client } => w.u32(*client),
            Msg::RoundStart { params, .. } => {
                self.encode_round_start_header(&mut w);
                encode_encoded(&mut w, params);
            }
            Msg::Update {
                round,
                client,
                base_version,
                delta,
                stats,
            } => {
                w.u32(*round);
                w.u32(*client);
                w.u32(*base_version);
                w.u64(stats.n_samples);
                w.f32(stats.train_loss);
                w.u32(stats.steps);
                w.f64(stats.compute_ms);
                w.f32(stats.update_var);
                encode_encoded(&mut w, delta);
            }
            Msg::Heartbeat { client, round } => {
                w.u32(*client);
                w.u32(*round);
            }
            Msg::RoundEnd {
                round,
                model_version,
            } => {
                w.u32(*round);
                w.u32(*model_version);
            }
            Msg::Abort { round } => w.u32(*round),
            Msg::Shutdown => {}
        }
        w.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(buf);
        let ver = r.u8()?;
        if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&ver) {
            bail!(
                "protocol version mismatch: got {ver}, \
                 want {MIN_PROTOCOL_VERSION}..={PROTOCOL_VERSION}"
            );
        }
        let tag = r.u8()?;
        let msg = match tag {
            1 => Msg::Register {
                client: r.u32()?,
                profile: decode_profile(&mut r)?,
            },
            2 => Msg::RegisterAck { client: r.u32()? },
            3 => {
                let round = r.u32()?;
                let model_version = r.u32()?;
                let deadline_ms = r.u64()?;
                let lr = r.f32()?;
                let mu = r.f32()?;
                let local_epochs = r.u32()?;
                let mask_seed = r.u64()?;
                let compression = CompressionConfig {
                    quant_bits: r.u8()?,
                    topk_frac: r.f32()?,
                    dropout_keep: r.f32()?,
                };
                Msg::RoundStart {
                    round,
                    model_version,
                    deadline_ms,
                    lr,
                    mu,
                    local_epochs,
                    mask_seed,
                    compression,
                    params: decode_encoded(&mut r)?,
                }
            }
            4 => {
                let round = r.u32()?;
                let client = r.u32()?;
                // v1 updates carry no base version; a synchronous
                // client's base is its round's model (version == round)
                let base_version = if ver >= 2 { r.u32()? } else { round };
                let stats = UpdateStats {
                    n_samples: r.u64()?,
                    train_loss: r.f32()?,
                    steps: r.u32()?,
                    compute_ms: r.f64()?,
                    update_var: r.f32()?,
                };
                Msg::Update {
                    round,
                    client,
                    base_version,
                    stats,
                    delta: decode_encoded(&mut r)?,
                }
            }
            5 => Msg::Heartbeat {
                client: r.u32()?,
                round: r.u32()?,
            },
            6 => Msg::RoundEnd {
                round: r.u32()?,
                model_version: r.u32()?,
            },
            7 => Msg::Abort { round: r.u32()? },
            8 => Msg::Shutdown,
            t => bail!("unknown message tag {t}"),
        };
        if !r.is_done() {
            bail!("trailing bytes after {} message", msg.name());
        }
        Ok(msg)
    }

    /// All `RoundStart` fields except the model payload (which is
    /// always encoded last, so a shared pre-encoded payload can be
    /// appended — or written separately — after this header).
    fn encode_round_start_header(&self, w: &mut Writer) {
        let Msg::RoundStart {
            round,
            model_version,
            deadline_ms,
            lr,
            mu,
            local_epochs,
            params: _,
            mask_seed,
            compression,
        } = self
        else {
            // lint:allow(panic_safety) encode-side only: private helper, both callers match RoundStart first; no wire input reaches it
            unreachable!("encode_round_start_header on {}", self.name());
        };
        w.u32(*round);
        w.u32(*model_version);
        w.u64(*deadline_ms);
        w.f32(*lr);
        w.f32(*mu);
        w.u32(*local_epochs);
        w.u64(*mask_seed);
        w.u8(compression.quant_bits);
        w.f32(compression.topk_frac);
        w.f32(compression.dropout_keep);
    }

    /// Encode, splitting off a shared trailing payload when one exists.
    ///
    /// For a `RoundStart` whose params are [`Encoded::PreEncoded`] this
    /// returns `(header bytes, Some(shared payload bytes))` — their
    /// concatenation is byte-identical to [`Msg::encode`], but the
    /// payload `Arc` is cloned instead of copied, so a transport can
    /// write the two parts back to back and a k-client broadcast never
    /// re-serializes (or re-copies) the model. Every other message
    /// returns `(encode(), None)`.
    pub fn encode_split(&self) -> (Vec<u8>, Option<Arc<[u8]>>) {
        if let Msg::RoundStart {
            params: Encoded::PreEncoded(p),
            ..
        } = self
        {
            let mut w = Writer::with_capacity(64);
            w.u8(PROTOCOL_VERSION);
            w.u8(self.tag());
            self.encode_round_start_header(&mut w);
            (w.into_vec(), Some(p.bytes.clone()))
        } else {
            (self.encode(), None)
        }
    }

    /// Payload size on the wire (encoded length).
    pub fn wire_bytes(&self) -> u64 {
        // cheap upper path: full encode for model-bearing messages would
        // double-copy; compute structurally instead
        match self {
            Msg::RoundStart { params, .. } => 40 + 2 + encoded_overhead(params),
            Msg::Update { delta, .. } => 34 + 2 + encoded_overhead(delta),
            _ => 16,
        }
    }
}

fn encoded_overhead(e: &Encoded) -> u64 {
    e.wire_bytes() + 16 // tag + length prefixes
}

fn encode_profile(w: &mut Writer, p: &ClientProfile) {
    w.f64(p.speed_factor);
    w.f64(p.mem_gb);
    w.f64(p.link_bw);
    w.u64(p.n_samples);
    w.f64(p.bench_step_ms);
}

fn decode_profile(r: &mut Reader) -> Result<ClientProfile> {
    Ok(ClientProfile {
        speed_factor: r.f64()?,
        mem_gb: r.f64()?,
        link_bw: r.f64()?,
        n_samples: r.u64()?,
        bench_step_ms: r.f64()?,
    })
}

fn encode_encoded(w: &mut Writer, e: &Encoded) {
    match e {
        Encoded::Dense(v) => {
            w.u8(0);
            w.f32_slice(v);
        }
        Encoded::QDense(q) => {
            w.u8(1);
            encode_quantized(w, q);
        }
        Encoded::Sparse(s) => {
            w.u8(2);
            w.u64(s.dense_len as u64);
            w.u32_slice(&s.idx);
            w.f32_slice(&s.val);
        }
        Encoded::QSparse { idx, q } => {
            w.u8(3);
            w.u32_slice(idx);
            encode_quantized(w, q);
        }
        Encoded::Masked {
            seed,
            keep,
            dense_len,
            inner,
        } => {
            w.u8(4);
            w.u64(*seed);
            w.f32(*keep);
            w.u64(*dense_len as u64);
            encode_encoded(w, inner);
        }
        // already-serialized bytes: splice verbatim (they carry their
        // own tag, so the wire stays identical to the inner encoding)
        Encoded::PreEncoded(p) => w.raw(&p.bytes),
    }
}

/// Serialize `e` once into a shareable [`PreEncoded`] payload.
///
/// Wrapping the result in [`Encoded::PreEncoded`] makes every
/// subsequent [`Msg::encode`] splice the same bytes (and every
/// in-process `Msg::clone` an `Arc` bump) instead of re-serializing —
/// the orchestrator uses this to encode a round's model broadcast
/// exactly once for all k recipients.
pub fn pre_encode(e: &Encoded) -> PreEncoded {
    if let Encoded::PreEncoded(p) = e {
        return p.clone();
    }
    let mut w = Writer::with_capacity(e.wire_bytes() as usize + 32);
    encode_encoded(&mut w, e);
    PreEncoded {
        bytes: w.into_vec().into(),
        dense_len: e.dense_len(),
        wire: e.wire_bytes(),
    }
}

/// [`pre_encode`] for a dense parameter vector, without materializing
/// an intermediate `Encoded::Dense` clone of the model.
pub fn pre_encode_dense(v: &[f32]) -> PreEncoded {
    let mut w = Writer::with_capacity(v.len() * 4 + 16);
    w.u8(0); // Encoded::Dense tag — must match encode_encoded
    w.f32_slice(v);
    PreEncoded {
        bytes: w.into_vec().into(),
        dense_len: v.len(),
        wire: 4 * v.len() as u64,
    }
}

/// Decode the bytes of a [`PreEncoded`] payload back into the
/// underlying encoding (never `PreEncoded` itself).
pub fn decode_payload(bytes: &[u8]) -> Result<Encoded> {
    let mut r = Reader::new(bytes);
    let e = decode_encoded(&mut r)?;
    if !r.is_done() {
        bail!("trailing bytes after encoded payload");
    }
    Ok(e)
}

/// Borrowed decode of a [`PreEncoded`] payload: parse the wire bytes
/// into a [`DecodedView`] whose index/value storage *is* the payload
/// buffer — no `Vec` is materialized for any encoding. Validation
/// (lengths, bounds, monotonic indices) is identical to
/// [`DecodedView::of`] over the decoded structures, because both paths
/// share the `from_parts_*` constructors.
pub fn view_payload<'a>(bytes: &'a [u8], n: usize) -> Result<DecodedView<'a>> {
    use crate::compress::{IdxSlice, ValSlice};
    let mut r = Reader::new(bytes);
    let view = match r.u8()? {
        0 => DecodedView::from_parts_dense(ValSlice::F32Le(r.f32_raw()?), n, "dense")?,
        1 => {
            let (vals, qn) = view_quantized(&mut r)?;
            if qn != n {
                bail!("qdense length {qn} != {n}");
            }
            DecodedView::from_parts_dense(vals, n, "qdense")?
        }
        2 => {
            let dense_len = r.u64()? as usize;
            if dense_len != n {
                bail!("sparse dense length {dense_len} != {n}");
            }
            let idx = IdxSlice::U32Le(r.u32_raw()?);
            let val = ValSlice::F32Le(r.f32_raw()?);
            DecodedView::from_parts_indexed(idx, val, n, "sparse")?
        }
        3 => {
            let idx = IdxSlice::U32Le(r.u32_raw()?);
            let (vals, qn) = view_quantized(&mut r)?;
            if qn != n {
                bail!("qsparse length {qn} != {n}");
            }
            DecodedView::from_parts_indexed(idx, vals, n, "qsparse")?
        }
        4 => {
            let seed = r.u64()?;
            let keep = r.f32()?;
            let dense_len = r.u64()? as usize;
            let vals = match r.u8()? {
                0 => ValSlice::F32Le(r.f32_raw()?),
                1 => view_quantized(&mut r)?.0,
                _ => bail!("masked inner must be dense-like"),
            };
            DecodedView::from_parts_masked(seed, keep, dense_len, vals, n)?
        }
        t => bail!("bad encoded tag {t}"),
    };
    if !r.is_done() {
        bail!("trailing bytes after encoded payload");
    }
    Ok(view)
}

/// Borrowed counterpart of [`decode_quantized`]: value bytes stay in
/// the payload buffer. Returns the value slice and the declared decoded
/// length `n`.
fn view_quantized<'a>(r: &mut Reader<'a>) -> Result<(crate::compress::ValSlice<'a>, usize)> {
    use crate::compress::ValSlice;
    let n = r.u64()? as usize;
    let scale = r.f32()?;
    let vals = match r.u8()? {
        8 => ValSlice::Q8 {
            v: r.i8_raw()?,
            scale,
        },
        16 => ValSlice::Q16Le {
            v: r.i16_raw()?,
            scale,
        },
        b => bail!("bad quantized bit width {b}"),
    };
    Ok((vals, n))
}

fn encode_quantized(w: &mut Writer, q: &Quantized) {
    w.u64(q.n as u64);
    w.f32(q.scale);
    match &q.data {
        QData::I8(v) => {
            w.u8(8);
            w.i8_slice(v);
        }
        QData::I16(v) => {
            w.u8(16);
            w.i16_slice(v);
        }
    }
}

fn decode_quantized(r: &mut Reader) -> Result<Quantized> {
    let n = r.u64()? as usize;
    let scale = r.f32()?;
    let bits = r.u8()?;
    let data = match bits {
        8 => QData::I8(r.i8_vec()?),
        16 => QData::I16(r.i16_vec()?),
        b => bail!("bad quantized bit width {b}"),
    };
    Ok(Quantized { data, scale, n })
}

fn decode_encoded(r: &mut Reader) -> Result<Encoded> {
    match r.u8()? {
        0 => Ok(Encoded::Dense(r.f32_vec()?)),
        1 => Ok(Encoded::QDense(decode_quantized(r)?)),
        2 => {
            let dense_len = r.u64()? as usize;
            let idx = r.u32_vec()?;
            let val = r.f32_vec()?;
            if idx.len() != val.len() {
                bail!("sparse arity mismatch");
            }
            Ok(Encoded::Sparse(Sparse {
                idx,
                val,
                dense_len,
            }))
        }
        3 => Ok(Encoded::QSparse {
            idx: r.u32_vec()?,
            q: decode_quantized(r)?,
        }),
        4 => {
            let seed = r.u64()?;
            let keep = r.f32()?;
            let dense_len = r.u64()? as usize;
            let inner = decode_encoded(r)?;
            if !matches!(inner, Encoded::Dense(_) | Encoded::QDense(_)) {
                bail!("masked inner must be dense-like");
            }
            Ok(Encoded::Masked {
                seed,
                keep,
                dense_len,
                inner: Box::new(inner),
            })
        }
        t => bail!("bad encoded tag {t}"),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::compress::compress;
    use crate::config::CompressionConfig as CC;
    use crate::util::rng::Rng;

    fn profile() -> ClientProfile {
        ClientProfile {
            speed_factor: 0.9,
            mem_gb: 16.0,
            link_bw: 1.25e9,
            n_samples: 512,
            bench_step_ms: 14.2,
        }
    }

    fn sample_msgs() -> Vec<Msg> {
        let mut rng = Rng::new(0);
        let v: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        vec![
            Msg::Register {
                client: 3,
                profile: profile(),
            },
            Msg::RegisterAck { client: 3 },
            Msg::RoundStart {
                round: 7,
                model_version: 7,
                deadline_ms: 60_000,
                lr: 0.05,
                mu: 0.01,
                local_epochs: 5,
                params: Encoded::Dense(v.clone()),
                mask_seed: 0xABCD,
                compression: CompressionConfig::PAPER,
            },
            Msg::Update {
                round: 7,
                client: 3,
                base_version: 5,
                delta: compress(&v, &CC::PAPER, 9),
                stats: UpdateStats {
                    n_samples: 512,
                    train_loss: 1.25,
                    steps: 80,
                    compute_ms: 912.5,
                    update_var: 0.002,
                },
            },
            Msg::Heartbeat {
                client: 3,
                round: 7,
            },
            Msg::RoundEnd {
                round: 7,
                model_version: 8,
            },
            Msg::Abort { round: 7 },
            Msg::Shutdown,
        ]
    }

    #[test]
    fn roundtrip_every_message() {
        for msg in sample_msgs() {
            let enc = msg.encode();
            let dec = Msg::decode(&enc).unwrap();
            assert_eq!(msg, dec, "roundtrip failed for {}", msg.name());
        }
    }

    #[test]
    fn roundtrip_every_encoded_variant() {
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        for cfg in [
            CC::NONE,
            CC {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            CC {
                quant_bits: 16,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            CC {
                quant_bits: 32,
                topk_frac: 0.2,
                dropout_keep: 1.0,
            },
            CC::PAPER,
        ] {
            let delta = compress(&v, &cfg, 5);
            let msg = Msg::Update {
                round: 1,
                client: 2,
                base_version: 1,
                delta: delta.clone(),
                stats: UpdateStats {
                    n_samples: 10,
                    train_loss: 0.5,
                    steps: 4,
                    compute_ms: 1.0,
                    update_var: 0.1,
                },
            };
            match Msg::decode(&msg.encode()).unwrap() {
                Msg::Update { delta: d2, .. } => assert_eq!(delta, d2),
                _ => unreachable!(),
            }
        }
    }

    /// Protocol-version compatibility: v1 frames (no `base_version` on
    /// Update) must still decode, with the base defaulting to the round
    /// tag — the synchronous-client semantics.
    #[test]
    fn legacy_v1_update_decodes_with_round_as_base() {
        let delta = vec![1.0f32, -2.0, 0.5];
        // hand-roll the v1 layout: version 1, tag 4, round, client,
        // stats, encoded delta (no base_version)
        let mut w = Writer::with_capacity(64);
        w.u8(1);
        w.u8(4);
        w.u32(9); // round
        w.u32(3); // client
        w.u64(128); // n_samples
        w.f32(0.75); // train_loss
        w.u32(11); // steps
        w.f64(42.5); // compute_ms
        w.f32(0.01); // update_var
        encode_encoded(&mut w, &Encoded::Dense(delta.clone()));
        let decoded = Msg::decode(&w.into_vec()).unwrap();
        assert_eq!(
            decoded,
            Msg::Update {
                round: 9,
                client: 3,
                base_version: 9,
                delta: Encoded::Dense(delta),
                stats: UpdateStats {
                    n_samples: 128,
                    train_loss: 0.75,
                    steps: 11,
                    compute_ms: 42.5,
                    update_var: 0.01,
                },
            }
        );
        // layout-identical messages decode from a v1 version byte too
        let mut shutdown_v1 = Msg::Shutdown.encode();
        shutdown_v1[0] = 1;
        assert_eq!(Msg::decode(&shutdown_v1).unwrap(), Msg::Shutdown);
        // versions below the window are still rejected
        let mut too_old = Msg::Shutdown.encode();
        too_old[0] = 0;
        assert!(Msg::decode(&too_old).is_err());
    }

    #[test]
    fn rejects_bad_version_tag_truncation_trailing() {
        let good = Msg::Shutdown.encode();
        let mut bad_ver = good.clone();
        bad_ver[0] = 99;
        assert!(Msg::decode(&bad_ver).is_err());

        let mut bad_tag = good.clone();
        bad_tag[1] = 200;
        assert!(Msg::decode(&bad_tag).is_err());

        let reg = sample_msgs()[0].encode();
        assert!(Msg::decode(&reg[..reg.len() - 3]).is_err());

        let mut trailing = good;
        trailing.push(0);
        assert!(Msg::decode(&trailing).is_err());
    }

    fn round_start(params: Encoded) -> Msg {
        Msg::RoundStart {
            round: 7,
            model_version: 7,
            deadline_ms: 60_000,
            lr: 0.05,
            mu: 0.01,
            local_epochs: 5,
            params,
            mask_seed: 0xABCD,
            compression: CompressionConfig::PAPER,
        }
    }

    #[test]
    fn pre_encoded_payload_is_wire_identical_to_inner() {
        let mut rng = Rng::new(4);
        let v: Vec<f32> = (0..400).map(|_| rng.normal() as f32).collect();
        let dense_msg = round_start(Encoded::Dense(v.clone()));
        let pre = pre_encode(&Encoded::Dense(v.clone()));
        assert_eq!(pre, pre_encode_dense(&v), "both constructors must agree");
        let shared_msg = round_start(Encoded::PreEncoded(pre));

        // byte-identical on the wire, protocol version unchanged
        assert_eq!(dense_msg.encode(), shared_msg.encode());
        assert_eq!(dense_msg.wire_bytes(), shared_msg.wire_bytes());
        // the receiver sees the inner encoding, never PreEncoded
        match Msg::decode(&shared_msg.encode()).unwrap() {
            Msg::RoundStart { params, .. } => assert_eq!(params, Encoded::Dense(v)),
            other => panic!("expected RoundStart, got {}", other.name()),
        }
    }

    #[test]
    fn encode_split_concatenates_to_full_encode() {
        let v = vec![1.5f32; 64];
        let shared = round_start(Encoded::PreEncoded(pre_encode_dense(&v)));
        let (head, payload) = shared.encode_split();
        let payload = payload.expect("shared payload expected");
        let mut joined = head;
        joined.extend_from_slice(&payload);
        assert_eq!(joined, shared.encode());

        // non-shared messages pass through whole
        let (whole, none) = Msg::Shutdown.encode_split();
        assert!(none.is_none());
        assert_eq!(whole, Msg::Shutdown.encode());
        let dense = round_start(Encoded::Dense(v));
        let (whole, none) = dense.encode_split();
        assert!(none.is_none());
        assert_eq!(whole, dense.encode());
    }

    #[test]
    fn decode_payload_roundtrips_and_rejects_trailing() {
        let v = vec![2.0f32, -3.0, 4.5];
        let pre = pre_encode_dense(&v);
        assert_eq!(decode_payload(&pre.bytes).unwrap(), Encoded::Dense(v));
        let mut trailing = pre.bytes.to_vec();
        trailing.push(0);
        assert!(decode_payload(&trailing).is_err());
    }

    #[test]
    fn decode_random_garbage_never_panics() {
        let mut rng = Rng::new(2);
        for len in [0usize, 1, 2, 7, 64, 1024] {
            for _ in 0..50 {
                let buf: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
                let _ = Msg::decode(&buf); // must not panic
            }
        }
    }

    #[test]
    fn wire_bytes_tracks_compression() {
        let v = vec![1.0f32; 10_000];
        let dense = Msg::Update {
            round: 0,
            client: 0,
            base_version: 0,
            delta: Encoded::Dense(v.clone()),
            stats: UpdateStats {
                n_samples: 1,
                train_loss: 0.0,
                steps: 1,
                compute_ms: 0.0,
                update_var: 0.0,
            },
        };
        let mut rng = Rng::new(3);
        let noisy: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32).collect();
        let compressed = Msg::Update {
            round: 0,
            client: 0,
            base_version: 0,
            delta: compress(&noisy, &CC::PAPER, 1),
            stats: UpdateStats {
                n_samples: 1,
                train_loss: 0.0,
                steps: 1,
                compute_ms: 0.0,
                update_var: 0.0,
            },
        };
        let ratio = compressed.wire_bytes() as f64 / dense.wire_bytes() as f64;
        assert!(ratio < 0.45, "paper compression should cut >55%: {ratio}");
        // wire_bytes ≈ encode().len()
        for m in [&dense, &compressed] {
            let est = m.wire_bytes() as f64;
            let real = m.encode().len() as f64;
            assert!(
                (est - real).abs() / real < 0.05,
                "estimate {est} vs real {real}"
            );
        }
    }
}
