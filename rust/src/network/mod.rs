//! Communication layer (paper §3.2 "Communication Layer").
//!
//! * [`message`] — the versioned wire protocol between orchestrator and
//!   clients (binary codec, no serde).
//! * [`transport`] — the `ServerTransport`/`ClientTransport` traits.
//! * [`inproc`] — channel-based transport: the "MPI" path for HPC-local
//!   simulation and the default for tests (microsecond latency).
//! * [`tcp`] — length-prefixed framed TCP: the "gRPC" path; actually
//!   crosses a socket, supports multi-process deployment.
//! * [`framing`] — the frame layer under `tcp`: length-prefixed frames
//!   with transparent, protocol-negotiated whole-frame compression
//!   (std-only LZ codec, 256 B threshold, v1/v2 interop).
//! * [`reactor`] — the server-side readiness-driven connection layer:
//!   a fixed reactor thread pool sweeping nonblocking sockets, bounded
//!   per-peer outboxes (backpressure), generation-tagged peer map, one
//!   deregistration path, idle/half-frame timeouts.
//! * [`shaper`] — per-link bandwidth/latency shaping + byte accounting,
//!   applied uniformly to either transport.

// Wire-reachable tree: a hostile or corrupt peer must produce an `Err`,
// never a panic. `fedhpc-lint` enforces the wider panic-safety rule
// (indexing, assert!, unreachable!); these attributes make the
// unwrap/expect subclass unwriteable even under plain clippy.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod framing;
pub mod inproc;
pub mod message;
pub mod reactor;
pub mod shaper;
pub mod tcp;
pub mod transport;

pub use message::{
    decode_payload, pre_encode, pre_encode_dense, ClientProfile, Msg, UpdateStats,
    MIN_PROTOCOL_VERSION, PROTOCOL_VERSION,
};
pub use shaper::{LinkShaper, TrafficLog};
pub use transport::{ClientTransport, ServerTransport};

/// Round a message belongs to, for traffic accounting (0 for
/// round-less control messages).
pub(crate) fn round_of(msg: &Msg) -> u32 {
    match msg {
        Msg::RoundStart { round, .. }
        | Msg::Update { round, .. }
        | Msg::Heartbeat { round, .. }
        | Msg::RoundEnd { round, .. }
        | Msg::Abort { round } => *round,
        _ => 0,
    }
}
