//! In-process transport: mpsc channels + shaped delivery.
//!
//! Plays the role of MPI on the HPC side (microsecond latency when
//! unshaped) and doubles as the default test transport. Bandwidth
//! emulation: each message is stamped with a due-time from the link
//! shaper at send; the receiver holds it until due — so a 45 MB model
//! on a WAN-class link genuinely arrives seconds later, without a real
//! slow socket.

use super::message::Msg;
use super::shaper::{LinkShaper, TrafficLog};
use super::transport::{ClientTransport, ServerTransport};
use crate::cluster::NodeId;
use anyhow::{anyhow, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Envelope<T> {
    due: Instant,
    seq: u64,
    payload: T,
}

/// Receiver that respects envelope due-times.
struct ShapedReceiver<T> {
    rx: Receiver<Envelope<T>>,
    /// Not-yet-due messages, ordered by due time.
    pending: BinaryHeap<Reverse<(Instant, u64, HeapSlot<T>)>>,
}

/// Wrapper so T needs no Ord — ordering uses (due, seq) only.
struct HeapSlot<T>(T);

impl<T> PartialEq for HeapSlot<T> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<T> Eq for HeapSlot<T> {}
impl<T> PartialOrd for HeapSlot<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapSlot<T> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<T> ShapedReceiver<T> {
    fn new(rx: Receiver<Envelope<T>>) -> Self {
        ShapedReceiver {
            rx,
            pending: BinaryHeap::new(),
        }
    }

    fn drain_channel(&mut self) {
        while let Ok(env) = self.rx.try_recv() {
            self.pending
                .push(Reverse((env.due, env.seq, HeapSlot(env.payload))));
        }
    }

    /// Pop the next due message, waiting up to `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        loop {
            self.drain_channel();
            let now = Instant::now();
            if let Some(Reverse((due, _, _))) = self.pending.peek() {
                let due = *due;
                if due <= now {
                    if let Some(Reverse((_, _, slot))) = self.pending.pop() {
                        return Some(slot.0);
                    }
                    continue;
                }
                // wait until the earliest of: message due, caller deadline
                let wait = due.min(deadline).saturating_duration_since(now);
                if wait.is_zero() && deadline <= now {
                    return None;
                }
                match self.rx.recv_timeout(wait.max(Duration::from_micros(50))) {
                    Ok(env) => self
                        .pending
                        .push(Reverse((env.due, env.seq, HeapSlot(env.payload)))),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        // senders gone; flush whatever is due eventually
                        if self.pending.is_empty() {
                            return None;
                        }
                    }
                }
                continue;
            }
            // nothing pending: block on the channel
            let now = Instant::now();
            if deadline <= now {
                return None;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(env) => self
                    .pending
                    .push(Reverse((env.due, env.seq, HeapSlot(env.payload)))),
                Err(_) => return None,
            }
        }
    }
}

use super::round_of;

/// Builder: creates the server endpoint and one client endpoint per
/// node, with per-client link shapers.
pub struct InprocHub {
    server_in_tx: Sender<Envelope<(NodeId, Msg)>>,
    server_rx: Arc<Mutex<ShapedReceiver<(NodeId, Msg)>>>,
    client_txs: Arc<Mutex<HashMap<NodeId, Sender<Envelope<Msg>>>>>,
    shapers: Arc<Mutex<HashMap<NodeId, LinkShaper>>>,
    traffic: Arc<TrafficLog>,
    seq: Arc<Mutex<u64>>,
}

impl InprocHub {
    pub fn new(traffic: Arc<TrafficLog>) -> Self {
        let (tx, rx) = channel();
        InprocHub {
            server_in_tx: tx,
            server_rx: Arc::new(Mutex::new(ShapedReceiver::new(rx))),
            client_txs: Arc::new(Mutex::new(HashMap::new())),
            shapers: Arc::new(Mutex::new(HashMap::new())),
            traffic,
            seq: Arc::new(Mutex::new(0)),
        }
    }

    /// Register a client with its link shaper; returns its endpoint.
    pub fn add_client(&self, id: NodeId, shaper: LinkShaper) -> InprocClient {
        let (tx, rx) = channel();
        crate::util::lock_unpoisoned(&self.client_txs).insert(id, tx);
        crate::util::lock_unpoisoned(&self.shapers).insert(id, shaper);
        InprocClient {
            id,
            shaper,
            to_server: self.server_in_tx.clone(),
            rx: Mutex::new(ShapedReceiver::new(rx)),
            traffic: self.traffic.clone(),
            seq: self.seq.clone(),
        }
    }

    /// The server endpoint (one per hub).
    pub fn server(&self) -> InprocServer {
        InprocServer {
            rx: self.server_rx.clone(),
            client_txs: self.client_txs.clone(),
            shapers: self.shapers.clone(),
            traffic: self.traffic.clone(),
            seq: self.seq.clone(),
        }
    }
}

pub struct InprocServer {
    rx: Arc<Mutex<ShapedReceiver<(NodeId, Msg)>>>,
    client_txs: Arc<Mutex<HashMap<NodeId, Sender<Envelope<Msg>>>>>,
    shapers: Arc<Mutex<HashMap<NodeId, LinkShaper>>>,
    traffic: Arc<TrafficLog>,
    seq: Arc<Mutex<u64>>,
}

impl ServerTransport for InprocServer {
    fn send_to(&self, to: NodeId, msg: &Msg) -> Result<()> {
        // `msg.clone()` below is what makes shared broadcasts cheap
        // here: a RoundStart carrying `Encoded::PreEncoded` clones an
        // Arc of the round's serialized model instead of the O(P)
        // parameter vector, so all k sends share one buffer.
        let bytes = msg.wire_bytes();
        let shaper = crate::util::lock_unpoisoned(&self.shapers)
            .get(&to)
            .copied()
            .unwrap_or_else(LinkShaper::unshaped);
        self.traffic.record_down(round_of(msg), bytes);
        let mut s = crate::util::lock_unpoisoned(&self.seq);
        *s += 1;
        let seq = *s;
        drop(s);
        let env = Envelope {
            due: Instant::now() + shaper.delay(bytes),
            seq,
            payload: msg.clone(),
        };
        crate::util::lock_unpoisoned(&self.client_txs)
            .get(&to)
            .ok_or_else(|| anyhow!("inproc: unknown client {to}"))?
            .send(env)
            .map_err(|_| anyhow!("inproc: client {to} disconnected"))
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<(NodeId, Msg)>> {
        Ok(crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout))
    }

    fn connected(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = crate::util::lock_unpoisoned(&self.client_txs)
            .keys()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }
}

pub struct InprocClient {
    id: NodeId,
    shaper: LinkShaper,
    to_server: Sender<Envelope<(NodeId, Msg)>>,
    rx: Mutex<ShapedReceiver<Msg>>,
    traffic: Arc<TrafficLog>,
    seq: Arc<Mutex<u64>>,
}

impl ClientTransport for InprocClient {
    fn send(&self, msg: &Msg) -> Result<()> {
        let bytes = msg.wire_bytes();
        self.traffic.record_up(round_of(msg), bytes);
        let mut s = crate::util::lock_unpoisoned(&self.seq);
        *s += 1;
        let seq = *s;
        drop(s);
        let env = Envelope {
            due: Instant::now() + self.shaper.delay(bytes),
            seq,
            payload: (self.id, msg.clone()),
        };
        self.to_server
            .send(env)
            .map_err(|_| anyhow!("inproc: server disconnected"))
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Msg>> {
        Ok(crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout))
    }

    fn id(&self) -> NodeId {
        self.id
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    fn hub() -> (InprocHub, Arc<TrafficLog>) {
        let traffic = Arc::new(TrafficLog::new());
        (InprocHub::new(traffic.clone()), traffic)
    }

    #[test]
    fn roundtrip_unshaped() {
        let (hub, _) = hub();
        let c1 = hub.add_client(1, LinkShaper::unshaped());
        let server = hub.server();
        c1.send(&Msg::Heartbeat {
            client: 1,
            round: 0,
        })
        .unwrap();
        let (from, msg) = server
            .recv_timeout(Duration::from_millis(200))
            .unwrap()
            .unwrap();
        assert_eq!(from, 1);
        assert!(matches!(msg, Msg::Heartbeat { client: 1, .. }));
        server.send_to(1, &Msg::RegisterAck { client: 1 }).unwrap();
        let got = c1.recv_timeout(Duration::from_millis(200)).unwrap().unwrap();
        assert_eq!(got, Msg::RegisterAck { client: 1 });
    }

    #[test]
    fn recv_times_out_cleanly() {
        let (hub, _) = hub();
        let _c = hub.add_client(1, LinkShaper::unshaped());
        let server = hub.server();
        let t0 = Instant::now();
        let r = server.recv_timeout(Duration::from_millis(50)).unwrap();
        assert!(r.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(45));
    }

    #[test]
    fn shaped_delivery_is_delayed_and_ordered() {
        let (hub, _) = hub();
        let slow = LinkShaper {
            bandwidth: 1e6, // 1 MB/s
            latency: Duration::from_millis(20),
            degradation: 1.0,
        };
        let c = hub.add_client(1, slow);
        let server = hub.server();
        // ~16 B message: delay ≈ latency ≈ 20 ms
        let t0 = Instant::now();
        c.send(&Msg::Heartbeat {
            client: 1,
            round: 1,
        })
        .unwrap();
        // not yet due
        assert!(server.recv_timeout(Duration::from_millis(2)).unwrap().is_none());
        let got = server.recv_timeout(Duration::from_millis(500)).unwrap();
        assert!(got.is_some());
        let waited = t0.elapsed();
        assert!(
            waited >= Duration::from_millis(15),
            "arrived too early: {waited:?}"
        );
    }

    #[test]
    fn unknown_client_send_errors() {
        let (hub, _) = hub();
        let server = hub.server();
        assert!(server.send_to(9, &Msg::Shutdown).is_err());
    }

    #[test]
    fn traffic_is_accounted_by_direction_and_round() {
        let (hub, traffic) = hub();
        let c = hub.add_client(1, LinkShaper::unshaped());
        let server = hub.server();
        c.send(&Msg::Heartbeat {
            client: 1,
            round: 3,
        })
        .unwrap();
        server
            .send_to(
                1,
                &Msg::RoundEnd {
                    round: 3,
                    model_version: 4,
                },
            )
            .unwrap();
        let (down, up) = traffic.round(3);
        assert!(down > 0 && up > 0);
    }

    #[test]
    fn multiple_clients_interleave() {
        let (hub, _) = hub();
        let clients: Vec<_> = (0..5u32)
            .map(|i| hub.add_client(i, LinkShaper::unshaped()))
            .collect();
        let server = hub.server();
        assert_eq!(server.connected(), vec![0, 1, 2, 3, 4]);
        for c in &clients {
            c.send(&Msg::Heartbeat {
                client: c.id(),
                round: 0,
            })
            .unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            let (from, _) = server
                .recv_timeout(Duration::from_millis(500))
                .unwrap()
                .unwrap();
            seen.insert(from);
        }
        assert_eq!(seen.len(), 5);
    }
}
