//! Link shaping + traffic accounting.
//!
//! The shaper answers one question per message: *when* does it arrive,
//! given the link's bandwidth/latency and the payload size. Transports
//! stamp each envelope with the computed due-time; receivers hold
//! messages until due. This reproduces the paper's bandwidth-
//! constrained behaviour (slow WAN clients take visibly longer to
//! upload a 45 MB model) without needing real slow links.
//!
//! [`TrafficLog`] aggregates per-round byte counts — the source of
//! Table 4 / ablation E6 numbers. Over the TCP transport the recorded
//! counts are true bytes-on-wire: frame header included, after frame
//! compression, and recorded only once a frame actually (fully) hits
//! the socket — a failed or still-queued send contributes nothing.

use crate::cluster::LinkClass;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Per-link shaping model.
#[derive(Debug, Clone, Copy)]
pub struct LinkShaper {
    /// Bytes per second.
    pub bandwidth: f64,
    /// One-way latency.
    pub latency: Duration,
    /// Multiplier for fault injection (≥1 slows the link).
    pub degradation: f64,
}

impl LinkShaper {
    pub fn from_class(class: LinkClass) -> Self {
        let (bw, lat_ms) = class.profile();
        LinkShaper {
            bandwidth: bw,
            latency: Duration::from_secs_f64(lat_ms / 1e3),
            degradation: 1.0,
        }
    }

    /// Unshaped (infinite bandwidth, zero latency) — unit tests.
    pub fn unshaped() -> Self {
        LinkShaper {
            bandwidth: f64::INFINITY,
            latency: Duration::ZERO,
            degradation: 1.0,
        }
    }

    /// Transfer delay for a payload of `bytes`.
    pub fn delay(&self, bytes: u64) -> Duration {
        if self.bandwidth.is_infinite() && self.latency.is_zero() {
            return Duration::ZERO;
        }
        let serialize_s = bytes as f64 / self.bandwidth * self.degradation;
        self.latency.mul_f64(self.degradation) + Duration::from_secs_f64(serialize_s)
    }
}

/// Thread-safe per-round traffic accounting.
#[derive(Debug, Default)]
pub struct TrafficLog {
    inner: Mutex<TrafficInner>,
}

#[derive(Debug, Default)]
struct TrafficInner {
    /// round -> (bytes down to clients, bytes up from clients)
    per_round: BTreeMap<u32, (u64, u64)>,
    total_down: u64,
    total_up: u64,
}

impl TrafficLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_down(&self, round: u32, bytes: u64) {
        let mut g = crate::util::lock_unpoisoned(&self.inner);
        g.per_round.entry(round).or_default().0 += bytes;
        g.total_down += bytes;
    }

    pub fn record_up(&self, round: u32, bytes: u64) {
        let mut g = crate::util::lock_unpoisoned(&self.inner);
        g.per_round.entry(round).or_default().1 += bytes;
        g.total_up += bytes;
    }

    /// (down, up) bytes for a round.
    pub fn round(&self, round: u32) -> (u64, u64) {
        crate::util::lock_unpoisoned(&self.inner)
            .per_round
            .get(&round)
            .copied()
            .unwrap_or((0, 0))
    }

    pub fn totals(&self) -> (u64, u64) {
        let g = crate::util::lock_unpoisoned(&self.inner);
        (g.total_down, g.total_up)
    }

    /// All rounds in order: (round, down, up).
    pub fn rounds(&self) -> Vec<(u32, u64, u64)> {
        crate::util::lock_unpoisoned(&self.inner)
            .per_round
            .iter()
            .map(|(&r, &(d, u))| (r, d, u))
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    #[test]
    fn unshaped_is_instant() {
        assert_eq!(LinkShaper::unshaped().delay(1 << 30), Duration::ZERO);
    }

    #[test]
    fn delay_scales_with_size_and_link() {
        let ib = LinkShaper::from_class(LinkClass::Infiniband);
        let wan = LinkShaper::from_class(LinkClass::CloudWan);
        let mb45 = 45 * 1024 * 1024;
        assert!(wan.delay(mb45) > ib.delay(mb45) * 20);
        assert!(wan.delay(2 * mb45) > wan.delay(mb45));
        // 45 MB over ~1 Gbit/s ≈ 0.38 s — sanity against the paper's
        // per-round payloads being seconds, not hours
        let d = wan.delay(mb45).as_secs_f64();
        assert!((0.1..10.0).contains(&d), "45MB WAN delay {d}s");
    }

    #[test]
    fn degradation_slows_link() {
        let mut s = LinkShaper::from_class(LinkClass::CloudLan);
        let base = s.delay(1_000_000);
        s.degradation = 4.0;
        assert!(s.delay(1_000_000) >= base * 3);
    }

    #[test]
    fn traffic_log_accumulates() {
        let log = TrafficLog::new();
        log.record_down(1, 100);
        log.record_down(1, 50);
        log.record_up(1, 30);
        log.record_up(2, 70);
        assert_eq!(log.round(1), (150, 30));
        assert_eq!(log.round(2), (0, 70));
        assert_eq!(log.round(99), (0, 0));
        assert_eq!(log.totals(), (150, 100));
        assert_eq!(log.rounds(), vec![(1, 150, 30), (2, 0, 70)]);
    }

    #[test]
    fn traffic_log_is_thread_safe() {
        let log = std::sync::Arc::new(TrafficLog::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let l = log.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    l.record_up(0, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.round(0).1, 8000);
    }
}
