//! Frame layer for the TCP transport: length-prefixed frames with a
//! transparently negotiated whole-frame compression flag.
//!
//! # Wire format
//!
//! Every frame is `[u32 LE header][payload]`. The low 31 bits of the
//! header are the payload length on the wire; bit 31 ([`COMPRESSED_FLAG`])
//! marks a compressed frame. This is backward compatible because the
//! frame bound has always been [`MAX_FRAME`] = 2³⁰: a v1/v2 peer reads
//! a flagged header as an absurd length and drops the connection, and we
//! never send compressed frames to such peers (see negotiation below).
//!
//! * **Uncompressed** (`flag = 0`): the payload is the `Msg::encode()`
//!   bytes, exactly as in protocol v1/v2.
//! * **Compressed** (`flag = 1`): the payload is
//!   `[u32 LE raw_len][LZ stream]`; decompressing the LZ stream must
//!   yield exactly `raw_len` bytes, which are the `Msg::encode()` bytes.
//!
//! # Compression policy
//!
//! Frames are compressed only when (a) the peer negotiated protocol
//! version ≥ `message::FRAME_COMPRESSION_VERSION`, (b) the logical
//! payload is at least [`MIN_COMPRESS`] = 256 bytes (don't compress
//! small control frames — the exemplar wire formats use the same
//! threshold), and (c) compression actually shrinks the payload.
//! Otherwise the uncompressed form is sent; decoders always accept
//! both. Frame compression is transparent to the application layer and
//! composes with (does not replace) the gradient codecs in `compress::`
//! — a quantized/sparse delta rides inside a compressed frame like any
//! other bytes.
//!
//! # Codec
//!
//! The LZ stream is a dependency-free LZSS variant: tokens are grouped
//! eight to a control byte (bit set ⇒ back-reference). A literal is one
//! byte; a back-reference is `[u16 LE offset][u8 length − 4]` with
//! offsets in `1..=65535` and match lengths in `4..=259`. The encoder
//! is greedy over a 2¹⁵-slot hash table of 4-byte prefixes. The decoder
//! is fully bounds-checked: truncated streams, bad offsets, and streams
//! that disagree with the declared `raw_len` are refused with an error,
//! never a panic.

use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::sync::Arc;

/// 1 GiB sanity bound on the logical (decompressed) frame payload.
pub const MAX_FRAME: u32 = 1 << 30;

/// Bit 31 of the frame header: payload is `[u32 raw_len][LZ stream]`.
pub const COMPRESSED_FLAG: u32 = 1 << 31;

/// Frames with logical payloads below this many bytes are never
/// compressed (zstd-exemplar threshold: "don't compress under 256 B").
pub const MIN_COMPRESS: usize = 256;

/// Bytes of the `[u32 LE]` frame header.
pub const FRAME_HEADER_BYTES: u64 = 4;

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 255 + MIN_MATCH;
const MAX_OFFSET: usize = u16::MAX as usize;
const HASH_BITS: u32 = 15;

#[inline]
fn read4(input: &[u8], i: usize) -> Option<[u8; 4]> {
    let end = i.checked_add(4)?;
    input.get(i..end)?.try_into().ok()
}

#[inline]
fn hash4(b: [u8; 4]) -> usize {
    let v = u32::from_le_bytes(b);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Greedy LZSS compression of `input`. Infallible; the output may be
/// larger than the input (the framing layer then keeps the raw form).
pub fn lz_compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut table = vec![u32::MAX; 1usize << HASH_BITS];
    let len = input.len();
    let mut i = 0usize;
    while i < len {
        let ctrl_at = out.len();
        out.push(0u8);
        let mut ctrl = 0u8;
        let mut slot = 0u32;
        while slot < 8 && i < len {
            let mut matched = 0usize;
            let mut offset = 0usize;
            if let Some(four) = read4(input, i) {
                let h = hash4(four);
                let cand = table.get(h).copied().unwrap_or(u32::MAX) as usize;
                if let Some(t) = table.get_mut(h) {
                    *t = i as u32;
                }
                if cand < i && i - cand <= MAX_OFFSET && read4(input, cand) == Some(four) {
                    let mut l = MIN_MATCH;
                    while l < MAX_MATCH
                        && input.get(i + l).is_some()
                        && input.get(i + l) == input.get(cand + l)
                    {
                        l += 1;
                    }
                    matched = l;
                    offset = i - cand;
                }
            }
            if matched >= MIN_MATCH {
                ctrl |= 1 << slot;
                out.extend_from_slice(&(offset as u16).to_le_bytes());
                out.push((matched - MIN_MATCH) as u8);
                i += matched;
            } else {
                if let Some(&b) = input.get(i) {
                    out.push(b);
                }
                i += 1;
            }
            slot += 1;
        }
        if let Some(c) = out.get_mut(ctrl_at) {
            *c = ctrl;
        }
    }
    out
}

/// Decompress an LZSS stream that must expand to exactly `raw_len`
/// bytes. Hostile input (truncation, bad offsets, length mismatch)
/// errors out; nothing here can panic.
pub fn lz_decompress(data: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    if raw_len > MAX_FRAME as usize {
        bail!("declared decompressed length too large: {raw_len}");
    }
    let mut out: Vec<u8> = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while pos < data.len() {
        if out.len() >= raw_len {
            bail!("compressed frame has trailing data");
        }
        let Some(&ctrl) = data.get(pos) else { break };
        pos += 1;
        let mut slot = 0u32;
        while slot < 8 {
            if out.len() == raw_len {
                if pos < data.len() {
                    bail!("compressed frame has trailing data");
                }
                break;
            }
            if pos >= data.len() {
                // the final group may cover fewer than 8 tokens — only
                // valid if the output is already complete (checked above)
                bail!("truncated compressed frame");
            }
            if ctrl & (1u8 << slot) != 0 {
                let (Some(&o0), Some(&o1), Some(&l0)) =
                    (data.get(pos), data.get(pos + 1), data.get(pos + 2))
                else {
                    bail!("truncated back-reference in compressed frame");
                };
                pos += 3;
                let offset = u16::from_le_bytes([o0, o1]) as usize;
                let mlen = l0 as usize + MIN_MATCH;
                if offset == 0 || offset > out.len() {
                    bail!("bad match offset {offset} at output position {}", out.len());
                }
                if out.len() + mlen > raw_len {
                    bail!("compressed frame expands past declared length {raw_len}");
                }
                // byte-at-a-time: matches may overlap their own output
                for _ in 0..mlen {
                    let Some(&b) = out.get(out.len() - offset) else {
                        bail!("bad match offset {offset}");
                    };
                    out.push(b);
                }
            } else {
                let Some(&b) = data.get(pos) else {
                    bail!("truncated literal in compressed frame");
                };
                pos += 1;
                out.push(b);
            }
            slot += 1;
        }
    }
    if out.len() != raw_len {
        bail!(
            "truncated compressed frame: produced {} of declared {raw_len} bytes",
            out.len()
        );
    }
    Ok(out)
}

/// Split a frame-header word into (payload length on the wire,
/// compressed flag), rejecting oversized lengths.
pub fn parse_header(word: u32) -> Result<(usize, bool)> {
    let compressed = word & COMPRESSED_FLAG != 0;
    let len = word & !COMPRESSED_FLAG;
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    Ok((len as usize, compressed))
}

fn header_word(len: usize, compressed: bool) -> Result<u32> {
    if len > MAX_FRAME as usize {
        bail!("frame too large: {len}");
    }
    let mut w = len as u32;
    if compressed {
        w |= COMPRESSED_FLAG;
    }
    Ok(w)
}

/// Decode a frame payload (the bytes after the header) into the logical
/// `Msg::encode()` bytes, honoring the header's compressed flag.
pub fn unframe(payload: &[u8], compressed: bool) -> Result<Vec<u8>> {
    if !compressed {
        return Ok(payload.to_vec());
    }
    let (Some(&a), Some(&b), Some(&c), Some(&d)) = (
        payload.first(),
        payload.get(1),
        payload.get(2),
        payload.get(3),
    ) else {
        bail!("compressed frame shorter than its raw-length prefix");
    };
    let raw_len = u32::from_le_bytes([a, b, c, d]);
    if raw_len > MAX_FRAME {
        bail!("declared decompressed length too large: {raw_len}");
    }
    let body = payload.get(4..).unwrap_or(&[]);
    lz_decompress(body, raw_len as usize)
}

/// One wire-ready frame (header included), kept in up to two segments
/// so an Arc-shared broadcast payload is never copied per peer.
#[derive(Clone, Debug)]
pub enum FrameBytes {
    /// Complete frame owned by one peer's outbox.
    Owned(Vec<u8>),
    /// `pre` = header + message head (owned); `shared` payload follows.
    Split { pre: Vec<u8>, shared: Arc<[u8]> },
    /// Complete frame shared across the cohort (compressed broadcast:
    /// the whole-frame bytes are identical for every recipient).
    Shared(Arc<[u8]>),
}

impl FrameBytes {
    /// Total bytes this frame occupies on the wire (header included).
    pub fn wire_len(&self) -> u64 {
        let (a, b) = self.segments();
        (a.len() + b.len()) as u64
    }

    /// The frame as two back-to-back byte segments.
    pub fn segments(&self) -> (&[u8], &[u8]) {
        match self {
            FrameBytes::Owned(v) => (v.as_slice(), &[]),
            FrameBytes::Split { pre, shared } => (pre.as_slice(), shared),
            FrameBytes::Shared(a) => (a, &[]),
        }
    }
}

/// Build the uncompressed frame for `head ++ shared`: the header and
/// head go into an owned prefix, the shared payload is Arc-appended.
pub fn frame_uncompressed(head: &[u8], shared: Option<&Arc<[u8]>>) -> Result<FrameBytes> {
    let tail_len = shared.map_or(0, |s| s.len());
    let word = header_word(head.len() + tail_len, false)?;
    let mut pre = Vec::with_capacity(4 + head.len());
    pre.extend_from_slice(&word.to_le_bytes());
    pre.extend_from_slice(head);
    Ok(match shared {
        Some(s) if !s.is_empty() => FrameBytes::Split {
            pre,
            shared: s.clone(),
        },
        _ => FrameBytes::Owned(pre),
    })
}

/// Try to build a complete compressed frame (header included) over
/// `head ++ tail`. Returns `None` when the payload is under
/// [`MIN_COMPRESS`] or when compression does not shrink it — the caller
/// then sends the uncompressed form.
pub fn try_frame_compressed(head: &[u8], tail: &[u8]) -> Result<Option<Vec<u8>>> {
    let raw_len = head.len() + tail.len();
    if raw_len < MIN_COMPRESS || raw_len > MAX_FRAME as usize {
        return Ok(None);
    }
    let lz = if tail.is_empty() {
        lz_compress(head)
    } else {
        let mut raw = Vec::with_capacity(raw_len);
        raw.extend_from_slice(head);
        raw.extend_from_slice(tail);
        lz_compress(&raw)
    };
    let payload_len = 4 + lz.len();
    if payload_len >= raw_len {
        return Ok(None);
    }
    let word = header_word(payload_len, true)?;
    let mut frame = Vec::with_capacity(4 + payload_len);
    frame.extend_from_slice(&word.to_le_bytes());
    frame.extend_from_slice(&(raw_len as u32).to_le_bytes());
    frame.extend_from_slice(&lz);
    Ok(Some(frame))
}

/// Build the frame for `head ++ shared`, compressing when `compress`
/// is set and profitable (see the module docs for the policy).
pub fn build_frame(head: &[u8], shared: Option<&Arc<[u8]>>, compress: bool) -> Result<FrameBytes> {
    if compress {
        let tail: &[u8] = shared.map_or(&[][..], |s| s);
        if let Some(frame) = try_frame_compressed(head, tail)? {
            return Ok(FrameBytes::Owned(frame));
        }
    }
    frame_uncompressed(head, shared)
}

/// Incremental frame parser for nonblocking reads: feed raw socket
/// bytes with [`extend`](FrameAssembler::extend), pop logical payloads
/// with [`next_frame`](FrameAssembler::next_frame).
#[derive(Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    pos: usize,
}

fn read_u32_le(buf: &[u8], at: usize) -> Option<u32> {
    let b0 = *buf.get(at)?;
    let b1 = *buf.get(at.checked_add(1)?)?;
    let b2 = *buf.get(at.checked_add(2)?)?;
    let b3 = *buf.get(at.checked_add(3)?)?;
    Some(u32::from_le_bytes([b0, b1, b2, b3]))
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler::default()
    }

    /// Append raw bytes read from the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        // reclaim the consumed prefix before growing further
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos >= 64 * 1024) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame's logical payload; `None` when more
    /// bytes are needed. Malformed headers or compressed bodies error.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        let Some(word) = read_u32_le(&self.buf, self.pos) else {
            return Ok(None);
        };
        let (len, compressed) = parse_header(word)?;
        let Some(start) = self.pos.checked_add(4) else {
            bail!("frame bounds overflow");
        };
        let Some(end) = start.checked_add(len) else {
            bail!("frame bounds overflow");
        };
        let Some(payload) = self.buf.get(start..end) else {
            return Ok(None);
        };
        let out = unframe(payload, compressed)?;
        self.pos = end;
        Ok(Some(out))
    }

    /// True when a started-but-incomplete frame is buffered — the
    /// half-frame (slowloris) condition the idle reaper keys on.
    pub fn mid_frame(&self) -> bool {
        self.pos < self.buf.len()
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }
}

/// Blocking read of one frame: returns the logical payload and the
/// bytes that crossed the wire (header included).
pub fn read_frame(stream: &mut impl Read) -> Result<(Vec<u8>, u64)> {
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let (len, compressed) = parse_header(u32::from_le_bytes(hdr))?;
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    let payload = unframe(&buf, compressed)?;
    Ok((payload, FRAME_HEADER_BYTES + len as u64))
}

/// Blocking write of a built frame; returns its wire length.
pub fn write_frame(stream: &mut impl Write, frame: &FrameBytes) -> Result<u64> {
    let (a, b) = frame.segments();
    stream.write_all(a)?;
    if !b.is_empty() {
        stream.write_all(b)?;
    }
    Ok(frame.wire_len())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) {
        let lz = lz_compress(data);
        let back = lz_decompress(&lz, data.len()).unwrap();
        assert_eq!(back, data, "lz roundtrip mismatch at len {}", data.len());
    }

    #[test]
    fn lz_roundtrips_basic_shapes() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abcd");
        roundtrip(b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa");
        roundtrip("hello hello hello hello hello hello".as_bytes());
        let long: Vec<u8> = (0..100_000u32).map(|i| (i % 7) as u8).collect();
        roundtrip(&long);
        // overlapping match (RLE-style): offset 1, long run
        let run = vec![42u8; 10_000];
        let lz = lz_compress(&run);
        assert!(lz.len() < run.len() / 8, "run should compress hard: {}", lz.len());
        roundtrip(&run);
    }

    #[test]
    fn lz_roundtrips_incompressible_random() {
        let mut rng = Rng::new(7);
        for len in [1usize, 5, 255, 256, 4096, 70_000] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn lz_decompress_refuses_hostile_input() {
        // declared length never produced
        assert!(lz_decompress(&[], 1).is_err());
        // truncated back-reference
        assert!(lz_decompress(&[0b1, 0x01], 8).is_err());
        // offset 0 and offset beyond output are both invalid
        assert!(lz_decompress(&[0b1, 0, 0, 0], 8).is_err());
        assert!(lz_decompress(&[0b1, 0xFF, 0xFF, 0], 8).is_err());
        // match expanding past the declared length
        assert!(lz_decompress(&[0, b'a', 0b1, 1, 0, 255], 6).is_err());
        // trailing data after the declared length is complete
        let mut lz = lz_compress(b"abc");
        lz.push(0);
        assert!(lz_decompress(&lz, 3).is_err());
        // declared length over the frame bound
        assert!(lz_decompress(&[0], MAX_FRAME as usize + 1).is_err());
        // valid stream, wrong declared length (too long)
        let lz = lz_compress(b"abcdef");
        assert!(lz_decompress(&lz, 7).is_err());
    }

    #[test]
    fn header_flag_and_bounds() {
        let (len, comp) = parse_header(1234).unwrap();
        assert_eq!((len, comp), (1234, false));
        let (len, comp) = parse_header(1234 | COMPRESSED_FLAG).unwrap();
        assert_eq!((len, comp), (1234, true));
        assert!(parse_header(MAX_FRAME + 1).is_err());
        assert!(parse_header((MAX_FRAME + 1) | COMPRESSED_FLAG).is_err());
    }

    #[test]
    fn small_or_unprofitable_payloads_stay_uncompressed() {
        // under the 256 B threshold: never compressed
        let head = vec![9u8; MIN_COMPRESS - 1];
        assert!(try_frame_compressed(&head, &[]).unwrap().is_none());
        let frame = build_frame(&head, None, true).unwrap();
        assert!(matches!(frame, FrameBytes::Owned(_)));
        let (a, _) = frame.segments();
        let word = read_u32_le(a, 0).unwrap();
        assert_eq!(word & COMPRESSED_FLAG, 0, "sub-threshold frame must be raw");
        // at/over the threshold but incompressible: falls back to raw
        let mut rng = Rng::new(3);
        let noise: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        assert!(try_frame_compressed(&noise, &[]).unwrap().is_none());
    }

    #[test]
    fn compressed_frame_roundtrips_through_assembler() {
        let head: Vec<u8> = b"header-bytes".to_vec();
        let tail: Vec<u8> = (0..10_000u32).map(|i| (i % 11) as u8).collect();
        let frame = try_frame_compressed(&head, &tail).unwrap().expect("compressible");
        let mut logical = head.clone();
        logical.extend_from_slice(&tail);
        assert!(frame.len() < logical.len() + 4, "must shrink on the wire");
        let mut asm = FrameAssembler::new();
        asm.extend(&frame);
        let got = asm.next_frame().unwrap().unwrap();
        assert_eq!(got, logical);
        assert!(!asm.mid_frame());
    }

    #[test]
    fn assembler_handles_split_and_back_to_back_frames() {
        let f1 = build_frame(b"first", None, false).unwrap();
        let shared: Arc<[u8]> = vec![7u8; 500].into();
        let f2 = build_frame(b"second", Some(&shared), true).unwrap();
        let mut wire = Vec::new();
        let (a, b) = f1.segments();
        wire.extend_from_slice(a);
        wire.extend_from_slice(b);
        let (a, b) = f2.segments();
        wire.extend_from_slice(a);
        wire.extend_from_slice(b);
        // feed one byte at a time: frames pop exactly when complete
        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for &byte in &wire {
            asm.extend(&[byte]);
            while let Some(p) = asm.next_frame().unwrap() {
                got.push(p);
            }
        }
        let mut expect2 = b"second".to_vec();
        expect2.extend_from_slice(&shared);
        assert_eq!(got, vec![b"first".to_vec(), expect2]);
        assert_eq!(asm.buffered(), 0);
        // half a header is mid-frame
        asm.extend(&[1, 0]);
        assert!(asm.mid_frame());
        assert!(asm.next_frame().unwrap().is_none());
    }

    #[test]
    fn hostile_compressed_frames_refused_without_panic() {
        let tail: Vec<u8> = (0..5_000u32).map(|i| (i % 13) as u8).collect();
        let frame = try_frame_compressed(b"", &tail).unwrap().expect("compressible");
        // truncate the body: assembler sees a complete frame whose LZ
        // stream is short — must error, not block or panic
        let mut cut = frame.clone();
        let body_len = cut.len() - 4 - 1;
        cut.truncate(cut.len() - 1);
        let word = (body_len as u32 + 4) | COMPRESSED_FLAG;
        cut.splice(..4, word.to_le_bytes());
        let mut asm = FrameAssembler::new();
        asm.extend(&cut);
        assert!(asm.next_frame().is_err());
        // inflate the declared raw_len past what the stream produces
        let mut over = frame.clone();
        over.splice(4..8, 1_000_000u32.to_le_bytes());
        let mut asm = FrameAssembler::new();
        asm.extend(&over);
        assert!(asm.next_frame().is_err());
        // declared raw_len above MAX_FRAME
        let mut huge = frame;
        huge.splice(4..8, (MAX_FRAME + 1).to_le_bytes());
        let mut asm = FrameAssembler::new();
        asm.extend(&huge);
        assert!(asm.next_frame().is_err());
    }

    #[test]
    fn blocking_read_write_roundtrip_both_forms() {
        for compress in [false, true] {
            let payload: Vec<u8> = (0..3_000u32).map(|i| (i % 9) as u8).collect();
            let frame = build_frame(&payload, None, compress).unwrap();
            let mut wire = Vec::new();
            let wrote = write_frame(&mut wire, &frame).unwrap();
            assert_eq!(wrote as usize, wire.len());
            let mut cursor = std::io::Cursor::new(wire);
            let (got, wire_bytes) = read_frame(&mut cursor).unwrap();
            assert_eq!(got, payload);
            assert_eq!(wire_bytes, wrote);
            if compress {
                assert!(wrote < payload.len() as u64, "patterned payload must shrink");
            }
        }
    }

    /// Property: arbitrary payloads round-trip bit-identically through
    /// the compressed framing, on both sides of the 256 B threshold.
    #[test]
    fn prop_framing_roundtrips_bit_identically() {
        crate::testkit::check("framing_roundtrip", 64, |g| {
            let len = g.usize_in(0, 2_048);
            let mode = g.rng.below(3);
            let data: Vec<u8> = (0..len)
                .map(|i| match mode {
                    0 => (g.rng.next_u32() & 0xFF) as u8, // noise
                    1 => (i % 17) as u8,                  // periodic
                    _ => ((i / 64) % 251) as u8,          // long runs
                })
                .collect();
            let split = g.usize_in(0, len);
            let head = data.get(..split).unwrap_or(&[]).to_vec();
            let tail: Arc<[u8]> = data.get(split..).unwrap_or(&[]).to_vec().into();
            let frame = build_frame(&head, Some(&tail), true).unwrap();
            let mut asm = FrameAssembler::new();
            let (a, b) = frame.segments();
            asm.extend(a);
            asm.extend(b);
            let got = asm
                .next_frame()
                .unwrap()
                .expect("complete frame must parse");
            assert_eq!(got, data, "roundtrip mismatch: len {len} mode {mode} split {split}");
        });
    }
}
