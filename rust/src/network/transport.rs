//! Transport abstraction: the orchestrator speaks `ServerTransport`,
//! workers speak `ClientTransport`; inproc ("MPI") and TCP ("gRPC")
//! implement both. All methods are blocking-with-timeout — the
//! framework's concurrency model is plain threads (see DESIGN.md).

use crate::cluster::NodeId;
use crate::network::message::Msg;
use anyhow::Result;
use std::time::Duration;

/// Server side: addressed send, any-source receive.
pub trait ServerTransport: Send {
    /// Send `msg` to a specific client.
    ///
    /// Broadcast contract: `msg` may carry a shared, pre-encoded
    /// payload (`Encoded::PreEncoded`, one `Arc` of serialized bytes
    /// per round). Implementations must treat the message as
    /// immutable and should forward the shared bytes (via
    /// `Msg::encode_split` / `Msg::clone`) rather than re-serializing
    /// the payload per recipient.
    fn send_to(&self, to: NodeId, msg: &Msg) -> Result<()>;

    /// Receive the next message from any client, waiting up to
    /// `timeout`. `Ok(None)` = timed out (not an error).
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<(NodeId, Msg)>>;

    /// Clients currently connected/known.
    fn connected(&self) -> Vec<NodeId>;
}

/// Client side: send to server, receive from server.
pub trait ClientTransport: Send {
    fn send(&self, msg: &Msg) -> Result<()>;
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Msg>>;
    fn id(&self) -> NodeId;
}
