//! Readiness-driven connection layer for the TCP server.
//!
//! Replaces the old thread-per-connection design with a small fixed
//! pool of reactor threads sweeping nonblocking sockets (DESIGN
//! rationale: a 10k-client fleet cannot afford 10k reader threads, and
//! the old path's global peer lock serialized every send behind the
//! slowest socket). The std library has no epoll binding, so readiness
//! is emulated: each reactor thread owns a disjoint set of connections
//! and sweeps them with nonblocking reads/writes, parking with a short
//! adaptive backoff when a sweep makes no progress and being unparked
//! by the accept loop or by [`Reactor::send_to`] enqueues.
//!
//! Key structural properties (each fixes a bug in the old transport):
//!
//! * **No socket I/O under the peer-map lock.** `send_to` locks the map
//!   only to clone the target's outbox handle; writes happen on the
//!   owning reactor thread. A stalled client can fill its own bounded
//!   outbox (further sends to *it* fail) but never delays sends to
//!   healthy peers, `connected()`, or deregistrations.
//! * **Generation-tagged registrations.** Every registration gets a
//!   fresh generation from a process-wide counter; deregistration
//!   removes the map entry only when the generation matches, so a
//!   re-registering peer's *old* connection can no longer evict the new
//!   stream or corrupt the active-connections gauge.
//! * **One deregistration path.** Every connection exit — EOF, read or
//!   write error, malformed frame, idle/half-frame timeout, server
//!   channel closed, replacement, shutdown — funnels through
//!   [`close_conn`], so the peer map, the per-server counters, and the
//!   `fedhpc_tcp_active_connections` gauge cannot drift.
//! * **Traffic recorded on completion only.** Bytes-on-wire (frame
//!   header + possibly-compressed payload) are recorded against
//!   [`TrafficLog`] when the frame fully flushes, never before.
//!
//! Backpressure: each peer has a bounded outbox
//! (`transport.outbox_frames`); enqueueing onto a full or closed outbox
//! errors immediately, which the orchestrator already treats as a
//! dropped client. Timeouts: connections that never register, stall
//! mid-frame (slowloris), or stop draining their outbox are reaped
//! after `transport.idle_timeout_ms`; registered peers that are merely
//! quiet are never reaped (long local training is normal).

use super::framing::{self, FrameAssembler, FrameBytes};
use super::message::Msg;
use super::shaper::TrafficLog;
use crate::cluster::NodeId;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::{self, Thread};
use std::time::{Duration, Instant};

/// Resolved reactor parameters (from `config::TransportConfig`).
#[derive(Clone, Debug)]
pub struct Tuning {
    pub reactor_threads: usize,
    pub max_connections: usize,
    pub compression: bool,
    pub idle_timeout: Duration,
    pub outbox_frames: usize,
}

impl Tuning {
    pub fn from_config(t: &crate::config::TransportConfig) -> Tuning {
        let threads = if t.reactor_threads == 0 {
            // auto: a handful of sweepers saturate a NIC long before
            // core count matters; cap so 128-core HPC nodes don't spin
            thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .clamp(1, 8)
        } else {
            t.reactor_threads as usize
        };
        Tuning {
            reactor_threads: threads.max(1),
            max_connections: t.max_connections.max(1),
            compression: t.compression,
            idle_timeout: Duration::from_millis(t.idle_timeout_ms.max(1)),
            outbox_frames: t.outbox_frames.max(1),
        }
    }
}

impl Default for Tuning {
    fn default() -> Tuning {
        Tuning::from_config(&crate::config::TransportConfig::default())
    }
}

/// One queued outbound frame plus its accounting metadata.
struct OutFrame {
    bytes: FrameBytes,
    round: u32,
    /// Logical (pre-compression) payload bytes, for the raw/wire ratio.
    raw_len: u64,
}

struct Outbox {
    q: VecDeque<OutFrame>,
    /// Set when the owning connection is gone or replaced: enqueues
    /// fail and the sweeping thread drops the connection.
    closed: bool,
}

struct PeerEntry {
    generation: u64,
    thread: usize,
    compress: bool,
    outbox: Arc<Mutex<Outbox>>,
}

struct Metrics {
    accepts: Arc<crate::telemetry::Counter>,
    active: Arc<crate::telemetry::Gauge>,
    outbox_depth: Arc<crate::telemetry::Gauge>,
    wakeups: Arc<crate::telemetry::Counter>,
    tx_raw: Arc<crate::telemetry::Counter>,
    tx_wire: Arc<crate::telemetry::Counter>,
    rx_wire: Arc<crate::telemetry::Counter>,
}

impl Metrics {
    fn bind() -> Metrics {
        use crate::telemetry::names;
        let g = crate::telemetry::global();
        Metrics {
            accepts: g.counter(
                names::TCP_ACCEPTS_TOTAL,
                "TCP connections accepted since process start.",
            ),
            active: g.gauge(
                names::TCP_ACTIVE_CONNECTIONS,
                "Registered TCP peers currently connected.",
            ),
            outbox_depth: g.gauge(
                names::TCP_OUTBOX_FRAMES,
                "Outbound frames queued across all peer outboxes.",
            ),
            wakeups: g.counter(
                names::TCP_REACTOR_WAKEUPS_TOTAL,
                "Reactor thread park/unpark wakeups.",
            ),
            tx_raw: g.counter(
                names::TCP_TX_RAW_BYTES_TOTAL,
                "Logical payload bytes sent, before frame compression.",
            ),
            tx_wire: g.counter(
                names::TCP_TX_WIRE_BYTES_TOTAL,
                "Bytes put on the wire (headers + possibly-compressed payloads).",
            ),
            rx_wire: g.counter(
                names::TCP_RX_WIRE_BYTES_TOTAL,
                "Bytes read off the wire (headers + possibly-compressed payloads).",
            ),
        }
    }
}

/// One-slot-per-head cache of compressed broadcast frames: a round's
/// Arc-shared payload is compressed once per distinct message head (the
/// planner may vary deadlines/epochs per client) and the resulting
/// whole-frame bytes are shared across the cohort.
struct BcastEntry {
    payload_ptr: usize,
    head: Vec<u8>,
    /// `None` records "compression unprofitable for this payload+head".
    frame: Option<Arc<[u8]>>,
}

const BCAST_CACHE_CAP: usize = 8;

/// The connection layer. Owned by `TcpServer`, shared with its accept
/// and reactor threads.
pub struct Reactor {
    tuning: Tuning,
    peers: Mutex<HashMap<NodeId, PeerEntry>>,
    /// Unpark handles, one per reactor thread (filled during start).
    threads: Mutex<Vec<Thread>>,
    stop: AtomicBool,
    next_generation: AtomicU64,
    /// Registered peers (distinct ids) — mirrors the global gauge but
    /// is per-server, so tests are immune to cross-test contamination.
    active_peers: AtomicUsize,
    /// Sockets currently owned by reactor threads (registered or not).
    open_conns: AtomicUsize,
    traffic: Arc<TrafficLog>,
    metrics: Metrics,
    bcast_cache: Mutex<VecDeque<BcastEntry>>,
}

impl Reactor {
    /// Spawn the accept loop and reactor pool over a bound listener.
    pub(crate) fn start(
        listener: TcpListener,
        tuning: Tuning,
        traffic: Arc<TrafficLog>,
        tx: Sender<(NodeId, Msg)>,
    ) -> Result<Arc<Reactor>> {
        listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        let r = Arc::new(Reactor {
            tuning: tuning.clone(),
            peers: Mutex::new(HashMap::new()),
            threads: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            next_generation: AtomicU64::new(0),
            active_peers: AtomicUsize::new(0),
            open_conns: AtomicUsize::new(0),
            traffic,
            metrics: Metrics::bind(),
            bcast_cache: Mutex::new(VecDeque::new()),
        });
        let mut queues: Vec<Arc<Mutex<Vec<TcpStream>>>> = Vec::new();
        for idx in 0..tuning.reactor_threads {
            let q: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
            queues.push(q.clone());
            let rt = r.clone();
            let txc = tx.clone();
            let handle = thread::Builder::new()
                .name(format!("tcp-reactor-{idx}"))
                .spawn(move || reactor_loop(&rt, idx, &q, &txc))
                .context("spawning reactor thread")?;
            crate::util::lock_unpoisoned(&r.threads).push(handle.thread().clone());
        }
        let rt = r.clone();
        thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || accept_loop(&rt, &listener, &queues))
            .context("spawning tcp accept thread")?;
        Ok(r)
    }

    /// Build and enqueue a frame onto `to`'s outbox. Never performs
    /// socket I/O and never blocks on another peer.
    pub(crate) fn send_to(&self, to: NodeId, msg: &Msg) -> Result<()> {
        let (outbox, thread_idx, compress) = {
            let peers = crate::util::lock_unpoisoned(&self.peers);
            let e = peers
                .get(&to)
                .ok_or_else(|| anyhow!("tcp: client {to} not connected"))?;
            (e.outbox.clone(), e.thread, e.compress)
        };
        let (head, shared) = msg.encode_split();
        let raw_len = (head.len() + shared.as_ref().map_or(0, |p| p.len())) as u64;
        let bytes = self.build_frame(&head, shared.as_ref(), compress)?;
        let round = super::round_of(msg);
        {
            let mut ob = crate::util::lock_unpoisoned(&outbox);
            if ob.closed {
                bail!("tcp: client {to} disconnected");
            }
            if ob.q.len() >= self.tuning.outbox_frames {
                bail!(
                    "tcp: client {to} outbox full ({} frames queued)",
                    ob.q.len()
                );
            }
            ob.q.push_back(OutFrame {
                bytes,
                round,
                raw_len,
            });
        }
        self.metrics.outbox_depth.inc();
        if let Some(t) = crate::util::lock_unpoisoned(&self.threads).get(thread_idx) {
            t.unpark();
        }
        Ok(())
    }

    fn build_frame(
        &self,
        head: &[u8],
        shared: Option<&Arc<[u8]>>,
        compress: bool,
    ) -> Result<FrameBytes> {
        if !compress {
            return framing::frame_uncompressed(head, shared);
        }
        let Some(payload) = shared else {
            // per-client frame: owned by one outbox, compress directly
            return framing::build_frame(head, None, true);
        };
        // broadcast frame: compress once per (payload, head) and share
        let key = payload.as_ptr() as usize;
        {
            let cache = crate::util::lock_unpoisoned(&self.bcast_cache);
            if let Some(hit) = cache
                .iter()
                .find(|e| e.payload_ptr == key && e.head == head)
            {
                return match &hit.frame {
                    Some(f) => Ok(FrameBytes::Shared(f.clone())),
                    None => framing::frame_uncompressed(head, Some(payload)),
                };
            }
        }
        let compressed = framing::try_frame_compressed(head, payload)?;
        let frame_arc: Option<Arc<[u8]>> = compressed.map(Arc::from);
        let out = match &frame_arc {
            Some(f) => FrameBytes::Shared(f.clone()),
            None => framing::frame_uncompressed(head, Some(payload))?,
        };
        let mut cache = crate::util::lock_unpoisoned(&self.bcast_cache);
        cache.push_front(BcastEntry {
            payload_ptr: key,
            head: head.to_vec(),
            frame: frame_arc,
        });
        cache.truncate(BCAST_CACHE_CAP);
        Ok(out)
    }

    /// Sorted ids of currently registered peers.
    pub(crate) fn connected(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = crate::util::lock_unpoisoned(&self.peers)
            .keys()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }

    /// Registered peers (what `fedhpc_tcp_active_connections` mirrors).
    pub(crate) fn active_peers(&self) -> usize {
        self.active_peers.load(Ordering::Acquire)
    }

    /// Live sockets including not-yet-registered ones.
    pub(crate) fn open_conns(&self) -> usize {
        self.open_conns.load(Ordering::Acquire)
    }

    /// Signal every thread to wind down (connections are closed through
    /// the usual deregistration path on their owning threads).
    pub(crate) fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        for t in crate::util::lock_unpoisoned(&self.threads).iter() {
            t.unpark();
        }
    }
}

fn accept_loop(r: &Arc<Reactor>, listener: &TcpListener, queues: &[Arc<Mutex<Vec<TcpStream>>>]) {
    if queues.is_empty() {
        return;
    }
    let mut next = 0usize;
    while !r.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                r.metrics.accepts.inc();
                if r.open_conns.load(Ordering::Acquire) >= r.tuning.max_connections {
                    log::warn!(
                        "tcp: refusing connection, at max_connections={}",
                        r.tuning.max_connections
                    );
                    continue; // stream dropped ⇒ RST/FIN to the peer
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                let idx = next % queues.len();
                next = next.wrapping_add(1);
                r.open_conns.fetch_add(1, Ordering::AcqRel);
                if let Some(q) = queues.get(idx) {
                    crate::util::lock_unpoisoned(q).push(stream);
                }
                if let Some(t) = crate::util::lock_unpoisoned(&r.threads).get(idx) {
                    t.unpark();
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_micros(500));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                log::warn!("tcp: accept error: {e}");
                thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Per-connection state owned by exactly one reactor thread.
struct Conn {
    stream: TcpStream,
    asm: FrameAssembler,
    outbox: Arc<Mutex<Outbox>>,
    /// Frame currently being flushed + its write offset.
    cur: Option<OutFrame>,
    cur_off: usize,
    /// `(id, generation)` once the peer has registered.
    peer: Option<(NodeId, u64)>,
    opened: Instant,
    last_read: Instant,
    last_write: Instant,
}

impl Conn {
    fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            asm: FrameAssembler::new(),
            outbox: Arc::new(Mutex::new(Outbox {
                q: VecDeque::new(),
                closed: false,
            })),
            cur: None,
            cur_off: 0,
            peer: None,
            opened: now,
            last_read: now,
            last_write: now,
        }
    }
}

fn reactor_loop(
    r: &Arc<Reactor>,
    idx: usize,
    incoming: &Arc<Mutex<Vec<TcpStream>>>,
    tx: &Sender<(NodeId, Msg)>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut idle_spins = 0u32;
    loop {
        if r.stop.load(Ordering::Acquire) {
            break;
        }
        let fresh: Vec<TcpStream> =
            std::mem::take(&mut *crate::util::lock_unpoisoned(incoming));
        let now = Instant::now();
        for stream in fresh {
            conns.push(Conn::new(stream, now));
        }
        let mut progress = false;
        let mut i = 0usize;
        while i < conns.len() {
            let Some(conn) = conns.get_mut(i) else { break };
            let (keep, prog) = sweep(r, idx, conn, &mut buf, tx, now);
            progress |= prog;
            if keep {
                i += 1;
            } else {
                let mut dead = conns.swap_remove(i);
                close_conn(r, &mut dead);
            }
        }
        if progress {
            idle_spins = 0;
            continue;
        }
        // idle: park with adaptive backoff (0.5 ms → 16 ms); unparked
        // early by enqueues and new connections
        idle_spins = idle_spins.saturating_add(1);
        let backoff = Duration::from_micros(500u64 << idle_spins.min(5) as u64);
        thread::park_timeout(backoff);
        r.metrics.wakeups.inc();
    }
    // shutdown: close every owned connection through the single path
    for mut c in conns.drain(..) {
        close_conn(r, &mut c);
    }
    for stream in crate::util::lock_unpoisoned(incoming).drain(..) {
        drop(stream);
        r.open_conns.fetch_sub(1, Ordering::AcqRel);
    }
}

/// One nonblocking pass over a connection: flush outbox, drain socket,
/// parse frames, check timeouts. Returns `(keep, made_progress)`.
fn sweep(
    r: &Reactor,
    idx: usize,
    conn: &mut Conn,
    buf: &mut [u8],
    tx: &Sender<(NodeId, Msg)>,
    now: Instant,
) -> (bool, bool) {
    let mut progress = false;

    // ---- writes: flush queued frames until empty or WouldBlock
    loop {
        if conn.cur.is_none() {
            let mut ob = crate::util::lock_unpoisoned(&conn.outbox);
            if ob.closed {
                // replaced by a re-registration: this socket is an orphan
                return (false, progress);
            }
            let Some(f) = ob.q.pop_front() else { break };
            drop(ob);
            r.metrics.outbox_depth.dec();
            conn.cur = Some(f);
            conn.cur_off = 0;
        }
        let Some(f) = conn.cur.as_ref() else { break };
        match write_step(&mut conn.stream, &f.bytes, &mut conn.cur_off) {
            Ok((done, wrote)) => {
                if wrote > 0 {
                    progress = true;
                    conn.last_write = now;
                }
                if !done {
                    break; // kernel buffer full — try next sweep
                }
                let wire = f.bytes.wire_len();
                r.traffic.record_down(f.round, wire);
                r.metrics.tx_wire.add(wire);
                r.metrics.tx_raw.add(f.raw_len);
                conn.cur = None;
            }
            Err(e) => {
                log::debug!("tcp: write error, dropping conn: {e}");
                return (false, progress);
            }
        }
    }

    // ---- reads: drain the socket (bounded per sweep for fairness)
    let mut chunks = 0u32;
    loop {
        match conn.stream.read(buf) {
            Ok(0) => return (false, progress), // peer closed
            Ok(n) => {
                progress = true;
                conn.last_read = now;
                let Some(chunk) = buf.get(..n) else {
                    return (false, progress);
                };
                conn.asm.extend(chunk);
                r.metrics.rx_wire.add(n as u64);
                chunks += 1;
                if n < buf.len() || chunks >= 8 {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) => {
                log::debug!("tcp: read error, dropping conn: {e}");
                return (false, progress);
            }
        }
    }

    // ---- parse every complete frame
    loop {
        match conn.asm.next_frame() {
            Ok(Some(payload)) => {
                if !handle_frame(r, idx, conn, tx, &payload) {
                    return (false, progress);
                }
            }
            Ok(None) => break,
            Err(e) => {
                let who = conn.peer.map_or(u32::MAX, |(id, _)| id);
                log::warn!("tcp: bad frame from peer {who}: {e}");
                return (false, progress);
            }
        }
    }

    // ---- timeouts: never-registered, half-frame stall, write stall.
    // Registered peers that are merely quiet are left alone.
    let idle = r.tuning.idle_timeout;
    if conn.peer.is_none() && now.duration_since(conn.opened) > idle {
        log::debug!("tcp: reaping connection that never registered");
        return (false, progress);
    }
    if conn.asm.mid_frame() && now.duration_since(conn.last_read) > idle {
        log::debug!("tcp: reaping half-frame (slowloris) connection");
        return (false, progress);
    }
    if conn.cur.is_some() && now.duration_since(conn.last_write) > idle {
        log::debug!("tcp: reaping write-stalled connection");
        return (false, progress);
    }
    (true, progress)
}

/// Write as much of `frame` as the kernel accepts, resuming at `*off`.
/// Returns `(frame_complete, bytes_written_now)`; WouldBlock is not an
/// error (returns incomplete), hard errors propagate.
fn write_step(
    stream: &mut TcpStream,
    frame: &FrameBytes,
    off: &mut usize,
) -> std::io::Result<(bool, usize)> {
    let (a, b) = frame.segments();
    let total = a.len() + b.len();
    let mut wrote = 0usize;
    while *off < total {
        let chunk = if *off < a.len() {
            a.get(*off..).unwrap_or(&[])
        } else {
            b.get(*off - a.len()..).unwrap_or(&[])
        };
        match stream.write(chunk) {
            Ok(0) => return Err(ErrorKind::WriteZero.into()),
            Ok(n) => {
                *off += n;
                wrote += n;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) => return Err(e),
        }
    }
    Ok((*off >= total, wrote))
}

/// Dispatch one decoded frame. Returns false to drop the connection.
fn handle_frame(
    r: &Reactor,
    idx: usize,
    conn: &mut Conn,
    tx: &Sender<(NodeId, Msg)>,
    payload: &[u8],
) -> bool {
    let msg = match Msg::decode(payload) {
        Ok(m) => m,
        Err(e) => {
            log::warn!("tcp: undecodable frame: {e}");
            return false;
        }
    };
    if let Some((id, _gen)) = conn.peer {
        // a same-id re-Register on the same socket is a profile refresh;
        // a different id on an established socket is a protocol error
        if let Msg::Register { client, .. } = &msg {
            if *client != id {
                log::warn!("tcp: peer {id} tried to re-register as {client}");
                return false;
            }
        }
        return tx.send((id, msg)).is_ok();
    }
    let Msg::Register { client, .. } = &msg else {
        log::warn!("tcp: first frame was {}, expected Register", msg.name());
        return false;
    };
    let id = *client;
    // negotiation: only peers speaking v3+ receive compressed frames
    let peer_version = payload.first().copied().unwrap_or(0);
    let compress =
        r.tuning.compression && peer_version >= super::message::FRAME_COMPRESSION_VERSION;
    let generation = r.next_generation.fetch_add(1, Ordering::AcqRel) + 1;
    conn.peer = Some((id, generation));
    {
        let mut peers = crate::util::lock_unpoisoned(&r.peers);
        let prev = peers.insert(
            id,
            PeerEntry {
                generation,
                thread: idx,
                compress,
                outbox: conn.outbox.clone(),
            },
        );
        match prev {
            Some(old) => {
                // the id stays connected through the NEW socket; poison
                // the old outbox so its owning thread drops the orphan
                crate::util::lock_unpoisoned(&old.outbox).closed = true;
            }
            None => {
                r.active_peers.fetch_add(1, Ordering::AcqRel);
                r.metrics.active.inc();
            }
        }
    }
    tx.send((id, msg)).is_ok()
}

/// The single deregistration path: every connection exit funnels here.
fn close_conn(r: &Reactor, conn: &mut Conn) {
    let dropped = {
        let mut ob = crate::util::lock_unpoisoned(&conn.outbox);
        ob.closed = true;
        let n = ob.q.len();
        ob.q.clear();
        n
    };
    for _ in 0..dropped {
        r.metrics.outbox_depth.dec();
    }
    if let Some((id, generation)) = conn.peer.take() {
        let mut peers = crate::util::lock_unpoisoned(&r.peers);
        let matches = peers
            .get(&id)
            .is_some_and(|e| e.generation == generation);
        if matches {
            peers.remove(&id);
            drop(peers);
            r.active_peers.fetch_sub(1, Ordering::AcqRel);
            r.metrics.active.dec();
        }
    }
    r.open_conns.fetch_sub(1, Ordering::AcqRel);
    conn.stream.shutdown(std::net::Shutdown::Both).ok();
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::network::message::ClientProfile;
    use std::sync::mpsc::channel;

    fn tiny_tuning() -> Tuning {
        Tuning {
            reactor_threads: 1,
            max_connections: 4,
            compression: true,
            idle_timeout: Duration::from_millis(200),
            outbox_frames: 2,
        }
    }

    fn register(id: NodeId) -> Msg {
        Msg::Register {
            client: id,
            profile: ClientProfile {
                speed_factor: 1.0,
                mem_gb: 1.0,
                link_bw: 1e9,
                n_samples: 1,
                bench_step_ms: 1.0,
            },
        }
    }

    fn wait_until(mut cond: impl FnMut() -> bool) -> bool {
        let start = Instant::now();
        while start.elapsed() < Duration::from_secs(5) {
            if cond() {
                return true;
            }
            thread::sleep(Duration::from_millis(10));
        }
        false
    }

    /// Regression (gauge/map leak): when the server-side channel is
    /// gone, a registering connection must still be deregistered — the
    /// old transport's reader thread early-returned and leaked the map
    /// entry and gauge increment forever.
    #[test]
    fn closed_server_channel_still_deregisters() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = channel();
        let r = Reactor::start(listener, tiny_tuning(), Arc::new(TrafficLog::new()), tx)
            .unwrap();
        drop(rx); // server consumer is gone
        let mut sock = TcpStream::connect(addr).unwrap();
        let frame = framing::build_frame(&register(9).encode(), None, false).unwrap();
        framing::write_frame(&mut sock, &frame).unwrap();
        // the register dispatch fails ⇒ the conn must fully deregister
        assert!(
            wait_until(|| r.active_peers() == 0 && r.open_conns() == 0),
            "conn leaked: active={} open={}",
            r.active_peers(),
            r.open_conns()
        );
        r.shutdown();
    }

    /// Connections that never register are reaped by the idle timeout.
    #[test]
    fn unregistered_connection_is_reaped() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, _rx) = channel();
        let r = Reactor::start(listener, tiny_tuning(), Arc::new(TrafficLog::new()), tx)
            .unwrap();
        let sock = TcpStream::connect(addr).unwrap();
        assert!(wait_until(|| r.open_conns() == 1));
        // no register, no bytes: the 200 ms idle timeout reaps it
        assert!(
            wait_until(|| r.open_conns() == 0),
            "idle unregistered conn not reaped"
        );
        drop(sock);
        r.shutdown();
    }

    /// The accept loop refuses connections over `max_connections`.
    #[test]
    fn connection_limit_is_enforced() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, _rx) = channel();
        let mut tuning = tiny_tuning();
        tuning.max_connections = 2;
        tuning.idle_timeout = Duration::from_secs(30);
        let r = Reactor::start(listener, tuning, Arc::new(TrafficLog::new()), tx).unwrap();
        let keep: Vec<TcpStream> = (0..2)
            .map(|_| TcpStream::connect(addr).unwrap())
            .collect();
        assert!(wait_until(|| r.open_conns() == 2));
        // the third connect is accepted at the OS level then dropped:
        // reading from it hits EOF quickly
        let mut extra = TcpStream::connect(addr).unwrap();
        extra
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut byte = [0u8; 1];
        let got = extra.read(&mut byte);
        assert!(
            matches!(got, Ok(0)) || got.is_err(),
            "over-limit conn should be closed"
        );
        assert_eq!(r.open_conns(), 2);
        drop(keep);
        r.shutdown();
    }
}
