//! Framed-TCP transport — the "gRPC" path (paper: cloud backend).
//!
//! Wire format: `[u32 LE header][payload]` per [`super::framing`] — the
//! low 31 header bits are the payload length, bit 31 flags transparent
//! whole-frame compression (negotiated: only sent to peers speaking
//! protocol v3+, so v1/v2 peers interop untouched). A real socket per
//! client; the server identifies each peer by its first message (which
//! must be `Register`).
//!
//! The server side is the readiness-driven [`super::reactor`]: a small
//! fixed pool of reactor threads sweeps all connections with
//! nonblocking I/O, `send_to` enqueues onto a bounded per-peer outbox
//! (backpressure: a full outbox errors instead of blocking), and one
//! deregistration path keeps the peer map and gauges exact. The client
//! side stays a plain blocking socket + reader thread — a worker owns
//! one connection, so per-connection threads are the right shape there.
//! Optional link shaping adds artificial delay on top of real socket
//! time (receiver-side hold, like inproc), sized by actual bytes on the
//! wire (post-compression, header included) — which is also exactly
//! what [`TrafficLog`] records, and only after a successful write.

use super::framing;
use super::message::{Msg, FRAME_COMPRESSION_VERSION};
use super::reactor::{Reactor, Tuning};
use super::shaper::{LinkShaper, TrafficLog};
use super::transport::{ClientTransport, ServerTransport};
use crate::cluster::NodeId;
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Server: accept loop + reactor thread pool (see [`super::reactor`]).
pub struct TcpServer {
    rx: Mutex<Receiver<(NodeId, Msg)>>,
    reactor: Arc<Reactor>,
    pub local_addr: std::net::SocketAddr,
}

impl TcpServer {
    /// Bind and start accepting with default transport tuning.
    /// `addr` like "127.0.0.1:0".
    pub fn bind(addr: &str, traffic: Arc<TrafficLog>) -> Result<TcpServer> {
        Self::bind_with(
            addr,
            &crate::config::TransportConfig::default(),
            traffic,
        )
    }

    /// Bind with explicit transport tuning (`transport.*` config).
    pub fn bind_with(
        addr: &str,
        cfg: &crate::config::TransportConfig,
        traffic: Arc<TrafficLog>,
    ) -> Result<TcpServer> {
        let listener =
            std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = channel::<(NodeId, Msg)>();
        let reactor = Reactor::start(listener, Tuning::from_config(cfg), traffic, tx)?;
        Ok(TcpServer {
            rx: Mutex::new(rx),
            reactor,
            local_addr,
        })
    }

    /// Registered peers on this server (what the process-wide
    /// `fedhpc_tcp_active_connections` gauge mirrors, but test-safe
    /// under parallel servers).
    pub fn active_connections(&self) -> usize {
        self.reactor.active_peers()
    }

    /// Live sockets on this server, including not-yet-registered ones.
    pub fn open_connections(&self) -> usize {
        self.reactor.open_conns()
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.reactor.shutdown();
    }
}

impl ServerTransport for TcpServer {
    fn send_to(&self, to: NodeId, msg: &Msg) -> Result<()> {
        // encode-once broadcast economics live in the reactor: shared
        // payloads ride as Arc segments (uncompressed) or a cohort-
        // shared compressed frame; enqueueing never touches a socket
        self.reactor.send_to(to, msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<(NodeId, Msg)>> {
        match crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout) {
            Ok(v) => Ok(Some(v)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn connected(&self) -> Vec<NodeId> {
        self.reactor.connected()
    }
}

/// Client: one connection + a reader thread.
pub struct TcpClient {
    id: NodeId,
    writer: Mutex<TcpStream>,
    rx: Mutex<Receiver<Msg>>,
    traffic: Arc<TrafficLog>,
    shaper: LinkShaper,
    /// Our side wants compression (config).
    compress: bool,
    /// Peer proved v3+ (set by the reader on the first inbound frame):
    /// only then do we start compressing uplink frames.
    peer_compresses: Arc<AtomicBool>,
}

impl TcpClient {
    /// Connect and immediately send `register` (must be Msg::Register).
    /// Frame compression is on (it still only engages once the server
    /// proves v3+); use [`connect_with`](Self::connect_with) to disable.
    pub fn connect(
        addr: &str,
        register: &Msg,
        shaper: LinkShaper,
        traffic: Arc<TrafficLog>,
    ) -> Result<TcpClient> {
        Self::connect_with(addr, register, shaper, traffic, true)
    }

    /// [`connect`](Self::connect) with explicit compression opt-in.
    pub fn connect_with(
        addr: &str,
        register: &Msg,
        shaper: LinkShaper,
        traffic: Arc<TrafficLog>,
        compression: bool,
    ) -> Result<TcpClient> {
        let id = match register {
            Msg::Register { client, .. } => *client,
            other => bail!("tcp connect needs a Register message, got {}", other.name()),
        };
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        // the Register always goes uncompressed: nothing is negotiated
        // yet (and it is far below the compression threshold anyway)
        let frame = framing::build_frame(&register.encode(), None, false)?;
        let wire = framing::write_frame(&mut stream, &frame)?;
        traffic.record_up(0, wire);
        let reader = stream.try_clone()?;
        let (tx, rx) = channel::<Msg>();
        let peer_compresses = Arc::new(AtomicBool::new(false));
        let flag = peer_compresses.clone();
        std::thread::Builder::new()
            .name(format!("tcp-client-{id}"))
            .spawn(move || {
                let mut reader = reader;
                loop {
                    match framing::read_frame(&mut reader) {
                        Ok((payload, _wire)) => {
                            // negotiation: any inbound v3+ frame proves
                            // the server decodes compressed frames
                            if payload.first().copied().unwrap_or(0)
                                >= FRAME_COMPRESSION_VERSION
                            {
                                flag.store(true, Ordering::Release);
                            }
                            match Msg::decode(&payload) {
                                Ok(m) => {
                                    if tx.send(m).is_err() {
                                        break;
                                    }
                                }
                                Err(e) => {
                                    log::warn!("tcp client: bad frame: {e}");
                                    break;
                                }
                            }
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning tcp client reader")?;
        Ok(TcpClient {
            id,
            writer: Mutex::new(stream),
            rx: Mutex::new(rx),
            traffic,
            shaper,
            compress: compression,
            peer_compresses,
        })
    }
}

impl ClientTransport for TcpClient {
    fn send(&self, msg: &Msg) -> Result<()> {
        let payload = msg.encode();
        let compress = self.compress && self.peer_compresses.load(Ordering::Acquire);
        let frame = framing::build_frame(&payload, None, compress)?;
        let wire = frame.wire_len();
        // emulate constrained uplink: hold before writing (the paper's
        // WAN clients really do take longer to upload) — sized by what
        // actually crosses the wire, so frame compression shortens it
        let delay = self.shaper.delay(wire);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        framing::write_frame(&mut *crate::util::lock_unpoisoned(&self.writer), &frame)?;
        // recorded only after the write succeeded, with real wire bytes
        self.traffic.record_up(super::round_of(msg), wire);
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Msg>> {
        match crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout) {
            Ok(v) => Ok(Some(v)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn id(&self) -> NodeId {
        self.id
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::network::message::ClientProfile;

    fn profile() -> ClientProfile {
        ClientProfile {
            speed_factor: 1.0,
            mem_gb: 1.0,
            link_bw: 1e9,
            n_samples: 10,
            bench_step_ms: 1.0,
        }
    }

    fn register(id: NodeId) -> Msg {
        Msg::Register {
            client: id,
            profile: profile(),
        }
    }

    #[test]
    fn connect_register_roundtrip() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(5), LinkShaper::unshaped(), traffic).unwrap();
        // server sees the Register first
        let (from, msg) = server
            .recv_timeout(Duration::from_secs(2))
            .unwrap()
            .unwrap();
        assert_eq!(from, 5);
        assert!(matches!(msg, Msg::Register { client: 5, .. }));
        // server -> client
        server.send_to(5, &Msg::RegisterAck { client: 5 }).unwrap();
        let got = client.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(got, Msg::RegisterAck { client: 5 });
        // client -> server again
        client
            .send(&Msg::Heartbeat {
                client: 5,
                round: 1,
            })
            .unwrap();
        let (_, hb) = server
            .recv_timeout(Duration::from_secs(2))
            .unwrap()
            .unwrap();
        assert!(matches!(hb, Msg::Heartbeat { .. }));
        assert_eq!(server.active_connections(), 1);
    }

    #[test]
    fn multiple_clients() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let clients: Vec<_> = (0..4u32)
            .map(|i| {
                TcpClient::connect(&addr, &register(i), LinkShaper::unshaped(), traffic.clone())
                    .unwrap()
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (from, _) = server
                .recv_timeout(Duration::from_secs(2))
                .unwrap()
                .unwrap();
            seen.insert(from);
        }
        assert_eq!(seen.len(), 4);
        for c in &clients {
            server
                .send_to(c.id(), &Msg::RoundEnd {
                    round: 0,
                    model_version: 1,
                })
                .unwrap();
            assert!(c.recv_timeout(Duration::from_secs(2)).unwrap().is_some());
        }
        let mut conn = server.connected();
        conn.sort_unstable();
        assert_eq!(conn, vec![0, 1, 2, 3]);
    }

    #[test]
    fn send_to_unknown_client_errors() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
        assert!(server.send_to(42, &Msg::Shutdown).is_err());
    }

    #[test]
    fn shared_payload_broadcast_roundtrips() {
        // a RoundStart carrying the round's pre-encoded (shared) model
        // payload must arrive byte-identically to a dense one
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(2), LinkShaper::unshaped(), traffic).unwrap();
        server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
        let params: Vec<f32> = (0..5_000).map(|i| i as f32 * 0.25).collect();
        let shared = crate::compress::Encoded::PreEncoded(super::super::message::pre_encode_dense(
            &params,
        ));
        server
            .send_to(
                2,
                &Msg::RoundStart {
                    round: 1,
                    model_version: 1,
                    deadline_ms: 1_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: shared,
                    mask_seed: 3,
                    compression: crate::config::CompressionConfig::NONE,
                },
            )
            .unwrap();
        let got = client.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        match got {
            Msg::RoundStart { params: p, .. } => {
                assert_eq!(p, crate::compress::Encoded::Dense(params));
            }
            other => panic!("expected RoundStart, got {}", other.name()),
        }
    }

    #[test]
    fn large_frame_roundtrip() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(1), LinkShaper::unshaped(), traffic).unwrap();
        server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
        // ~4 MB model payload
        let params: Vec<f32> = (0..1_000_000).map(|i| i as f32).collect();
        client
            .send(&Msg::Update {
                round: 1,
                client: 1,
                base_version: 1,
                delta: crate::compress::Encoded::Dense(params.clone()),
                stats: super::super::message::UpdateStats {
                    n_samples: 1,
                    train_loss: 0.0,
                    steps: 1,
                    compute_ms: 0.0,
                    update_var: 0.0,
                },
            })
            .unwrap();
        let (_, msg) = server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        match msg {
            Msg::Update { delta, .. } => match delta {
                crate::compress::Encoded::Dense(v) => assert_eq!(v, params),
                _ => unreachable!(),
            },
            _ => unreachable!(),
        }
    }

    /// Both directions flow compressed once negotiation completes, and
    /// payloads still arrive bit-identically.
    #[test]
    fn negotiated_compression_roundtrips() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(7), LinkShaper::unshaped(), traffic).unwrap();
        server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
        // server → client: a highly compressible broadcast
        let params: Vec<f32> = vec![0.5f32; 50_000];
        let pre = super::super::message::pre_encode_dense(&params);
        server
            .send_to(
                7,
                &Msg::RoundStart {
                    round: 1,
                    model_version: 1,
                    deadline_ms: 1_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: crate::compress::Encoded::PreEncoded(pre),
                    mask_seed: 0,
                    compression: crate::config::CompressionConfig::NONE,
                },
            )
            .unwrap();
        let got = client.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        match got {
            Msg::RoundStart { params: p, .. } => {
                assert_eq!(p, crate::compress::Encoded::Dense(params.clone()));
            }
            other => panic!("expected RoundStart, got {}", other.name()),
        }
        // having seen a v3 frame, the client now compresses its uplink
        assert!(client.peer_compresses.load(Ordering::Acquire));
        client
            .send(&Msg::Update {
                round: 1,
                client: 7,
                base_version: 1,
                delta: crate::compress::Encoded::Dense(params.clone()),
                stats: super::super::message::UpdateStats {
                    n_samples: 1,
                    train_loss: 0.0,
                    steps: 1,
                    compute_ms: 0.0,
                    update_var: 0.0,
                },
            })
            .unwrap();
        let (_, msg) = server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        match msg {
            Msg::Update { delta, .. } => {
                assert_eq!(delta, crate::compress::Encoded::Dense(params));
            }
            other => panic!("expected Update, got {}", other.name()),
        }
        // the constant-valued upload must have shrunk on the wire
        let up: u64 = traffic.totals().1;
        assert!(
            up < 100_000,
            "200 KB constant payload should compress hard, wire={up}"
        );
    }
}
