//! Framed-TCP transport — the "gRPC" path (paper: cloud backend).
//!
//! Wire format: `[u32 frame length][Msg::encode() bytes]`. A real
//! socket per client; the server accepts connections and identifies
//! each peer by its first message (which must be `Register`). Reader
//! threads decode frames and feed a shared queue; writes go through a
//! per-peer mutexed stream. Optional link shaping adds artificial
//! delay on top of real socket time (receiver-side hold, like inproc).

use super::message::Msg;
use super::shaper::{LinkShaper, TrafficLog};
use super::transport::{ClientTransport, ServerTransport};
use crate::cluster::NodeId;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const MAX_FRAME: u32 = 1 << 30; // 1 GiB sanity bound

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    write_frame_parts(stream, payload, &[])
}

/// Write one frame from two parts without concatenating them — the
/// broadcast path sends a per-client header followed by the round's
/// shared (pre-encoded) model payload, so nothing is copied per send.
fn write_frame_parts(stream: &mut TcpStream, head: &[u8], tail: &[u8]) -> Result<()> {
    let len = head.len() + tail.len();
    if len > MAX_FRAME as usize {
        bail!("frame too large: {len}");
    }
    stream.write_all(&(len as u32).to_le_bytes())?;
    stream.write_all(head)?;
    if !tail.is_empty() {
        stream.write_all(tail)?;
    }
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

/// Server: accept loop + per-connection reader threads.
pub struct TcpServer {
    rx: Mutex<Receiver<(NodeId, Msg)>>,
    peers: Arc<Mutex<HashMap<NodeId, TcpStream>>>,
    traffic: Arc<TrafficLog>,
    pub local_addr: std::net::SocketAddr,
}

impl TcpServer {
    /// Bind and start accepting. `addr` like "127.0.0.1:0".
    pub fn bind(addr: &str, traffic: Arc<TrafficLog>) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = channel::<(NodeId, Msg)>();
        let peers: Arc<Mutex<HashMap<NodeId, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let peers_accept = peers.clone();
        // telemetry handles resolved once at bind; per-event cost is a
        // relaxed atomic op (see crate::telemetry accuracy contract)
        let g = crate::telemetry::global();
        let accepts = g.counter(
            crate::telemetry::names::TCP_ACCEPTS_TOTAL,
            "TCP connections accepted since process start.",
        );
        let active = g.gauge(
            crate::telemetry::names::TCP_ACTIVE_CONNECTIONS,
            "Registered TCP peers currently connected.",
        );
        std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    let Ok(mut stream) = conn else { continue };
                    accepts.inc();
                    let tx = tx.clone();
                    let peers = peers_accept.clone();
                    let active = active.clone();
                    std::thread::Builder::new()
                        .name("tcp-read".into())
                        .spawn(move || {
                            // first frame must identify the peer
                            let Ok(first) = read_frame(&mut stream) else {
                                return;
                            };
                            let Ok(msg) = Msg::decode(&first) else {
                                log::warn!("tcp: undecodable first frame, dropping conn");
                                return;
                            };
                            let id = match &msg {
                                Msg::Register { client, .. } => *client,
                                other => {
                                    log::warn!(
                                        "tcp: first frame was {}, expected Register",
                                        other.name()
                                    );
                                    return;
                                }
                            };
                            if let Ok(w) = stream.try_clone() {
                                // a re-registering peer replaces its old
                                // stream — the gauge counts distinct ids
                                if crate::util::lock_unpoisoned(&peers)
                                    .insert(id, w)
                                    .is_none()
                                {
                                    active.inc();
                                }
                            }
                            if tx.send((id, msg)).is_err() {
                                return;
                            }
                            loop {
                                match read_frame(&mut stream) {
                                    Ok(buf) => match Msg::decode(&buf) {
                                        Ok(m) => {
                                            if tx.send((id, m)).is_err() {
                                                break;
                                            }
                                        }
                                        Err(e) => {
                                            log::warn!("tcp: bad frame from {id}: {e}");
                                            break;
                                        }
                                    },
                                    Err(_) => break, // peer closed
                                }
                            }
                            if crate::util::lock_unpoisoned(&peers).remove(&id).is_some() {
                                active.dec();
                            }
                        })
                        .ok();
                }
            })
            .context("spawning tcp accept thread")?;
        Ok(TcpServer {
            rx: Mutex::new(rx),
            peers,
            traffic,
            local_addr,
        })
    }
}

impl ServerTransport for TcpServer {
    fn send_to(&self, to: NodeId, msg: &Msg) -> Result<()> {
        // shared payloads (pre-encoded broadcasts) are written as a
        // second frame part: serialized once per round, not per client
        let (head, shared) = msg.encode_split();
        let total = head.len() + shared.as_ref().map_or(0, |p| p.len());
        self.traffic.record_down(super::round_of(msg), total as u64);
        let mut peers = crate::util::lock_unpoisoned(&self.peers);
        let stream = peers
            .get_mut(&to)
            .ok_or_else(|| anyhow!("tcp: client {to} not connected"))?;
        match shared {
            None => write_frame(stream, &head),
            Some(payload) => write_frame_parts(stream, &head, &payload),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<(NodeId, Msg)>> {
        match crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout) {
            Ok(v) => Ok(Some(v)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn connected(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = crate::util::lock_unpoisoned(&self.peers)
            .keys()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }
}

/// Client: one connection + a reader thread.
pub struct TcpClient {
    id: NodeId,
    writer: Mutex<TcpStream>,
    rx: Mutex<Receiver<Msg>>,
    traffic: Arc<TrafficLog>,
    shaper: LinkShaper,
}

impl TcpClient {
    /// Connect and immediately send `register` (must be Msg::Register).
    pub fn connect(
        addr: &str,
        register: &Msg,
        shaper: LinkShaper,
        traffic: Arc<TrafficLog>,
    ) -> Result<TcpClient> {
        let id = match register {
            Msg::Register { client, .. } => *client,
            other => bail!("tcp connect needs a Register message, got {}", other.name()),
        };
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let payload = register.encode();
        traffic.record_up(0, payload.len() as u64);
        write_frame(&mut stream, &payload)?;
        let reader = stream.try_clone()?;
        let (tx, rx) = channel::<Msg>();
        std::thread::Builder::new()
            .name(format!("tcp-client-{id}"))
            .spawn(move || {
                let mut reader = reader;
                loop {
                    match read_frame(&mut reader) {
                        Ok(buf) => match Msg::decode(&buf) {
                            Ok(m) => {
                                if tx.send(m).is_err() {
                                    break;
                                }
                            }
                            Err(e) => {
                                log::warn!("tcp client: bad frame: {e}");
                                break;
                            }
                        },
                        Err(_) => break,
                    }
                }
            })
            .context("spawning tcp client reader")?;
        Ok(TcpClient {
            id,
            writer: Mutex::new(stream),
            rx: Mutex::new(rx),
            traffic,
            shaper,
        })
    }
}

impl ClientTransport for TcpClient {
    fn send(&self, msg: &Msg) -> Result<()> {
        let payload = msg.encode();
        self.traffic
            .record_up(super::round_of(msg), payload.len() as u64);
        // emulate constrained uplink: hold before writing (the paper's
        // WAN clients really do take longer to upload)
        let delay = self.shaper.delay(payload.len() as u64);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        write_frame(&mut crate::util::lock_unpoisoned(&self.writer), &payload)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Msg>> {
        match crate::util::lock_unpoisoned(&self.rx).recv_timeout(timeout) {
            Ok(v) => Ok(Some(v)),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(None),
        }
    }

    fn id(&self) -> NodeId {
        self.id
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::network::message::ClientProfile;

    fn profile() -> ClientProfile {
        ClientProfile {
            speed_factor: 1.0,
            mem_gb: 1.0,
            link_bw: 1e9,
            n_samples: 10,
            bench_step_ms: 1.0,
        }
    }

    fn register(id: NodeId) -> Msg {
        Msg::Register {
            client: id,
            profile: profile(),
        }
    }

    #[test]
    fn connect_register_roundtrip() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(5), LinkShaper::unshaped(), traffic).unwrap();
        // server sees the Register first
        let (from, msg) = server
            .recv_timeout(Duration::from_secs(2))
            .unwrap()
            .unwrap();
        assert_eq!(from, 5);
        assert!(matches!(msg, Msg::Register { client: 5, .. }));
        // server -> client
        server.send_to(5, &Msg::RegisterAck { client: 5 }).unwrap();
        let got = client.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(got, Msg::RegisterAck { client: 5 });
        // client -> server again
        client
            .send(&Msg::Heartbeat {
                client: 5,
                round: 1,
            })
            .unwrap();
        let (_, hb) = server
            .recv_timeout(Duration::from_secs(2))
            .unwrap()
            .unwrap();
        assert!(matches!(hb, Msg::Heartbeat { .. }));
    }

    #[test]
    fn multiple_clients() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let clients: Vec<_> = (0..4u32)
            .map(|i| {
                TcpClient::connect(&addr, &register(i), LinkShaper::unshaped(), traffic.clone())
                    .unwrap()
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (from, _) = server
                .recv_timeout(Duration::from_secs(2))
                .unwrap()
                .unwrap();
            seen.insert(from);
        }
        assert_eq!(seen.len(), 4);
        for c in &clients {
            server
                .send_to(c.id(), &Msg::RoundEnd {
                    round: 0,
                    model_version: 1,
                })
                .unwrap();
            assert!(c.recv_timeout(Duration::from_secs(2)).unwrap().is_some());
        }
        let mut conn = server.connected();
        conn.sort_unstable();
        assert_eq!(conn, vec![0, 1, 2, 3]);
    }

    #[test]
    fn send_to_unknown_client_errors() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
        assert!(server.send_to(42, &Msg::Shutdown).is_err());
    }

    #[test]
    fn shared_payload_broadcast_roundtrips() {
        // a RoundStart carrying the round's pre-encoded (shared) model
        // payload must arrive byte-identically to a dense one
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(2), LinkShaper::unshaped(), traffic).unwrap();
        server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
        let params: Vec<f32> = (0..5_000).map(|i| i as f32 * 0.25).collect();
        let shared = crate::compress::Encoded::PreEncoded(super::super::message::pre_encode_dense(
            &params,
        ));
        server
            .send_to(
                2,
                &Msg::RoundStart {
                    round: 1,
                    model_version: 1,
                    deadline_ms: 1_000,
                    lr: 0.1,
                    mu: 0.0,
                    local_epochs: 1,
                    params: shared,
                    mask_seed: 3,
                    compression: crate::config::CompressionConfig::NONE,
                },
            )
            .unwrap();
        let got = client.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        match got {
            Msg::RoundStart { params: p, .. } => {
                assert_eq!(p, crate::compress::Encoded::Dense(params));
            }
            other => panic!("expected RoundStart, got {}", other.name()),
        }
    }

    #[test]
    fn large_frame_roundtrip() {
        let traffic = Arc::new(TrafficLog::new());
        let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
        let addr = server.local_addr.to_string();
        let client =
            TcpClient::connect(&addr, &register(1), LinkShaper::unshaped(), traffic).unwrap();
        server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
        // ~4 MB model payload
        let params: Vec<f32> = (0..1_000_000).map(|i| i as f32).collect();
        client
            .send(&Msg::Update {
                round: 1,
                client: 1,
                base_version: 1,
                delta: crate::compress::Encoded::Dense(params.clone()),
                stats: super::super::message::UpdateStats {
                    n_samples: 1,
                    train_loss: 0.0,
                    steps: 1,
                    compute_ms: 0.0,
                    update_var: 0.0,
                },
            })
            .unwrap();
        let (_, msg) = server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        match msg {
            Msg::Update { delta, .. } => match delta {
                crate::compress::Encoded::Dense(v) => assert_eq!(v, params),
                _ => unreachable!(),
            },
            _ => unreachable!(),
        }
    }
}
