//! Telemetry hot-path overhead: what one metric event costs, and what
//! instrumentation adds to a realistic ingest fold.
//!
//! The PR 7 acceptance bar is <1% added wall time on the server's
//! ingest path with telemetry always-on. Every instrumentation site
//! resolves its `Arc<Counter>`/`Arc<Histogram>` handle once (at
//! construction or behind a `OnceLock`), so the steady-state cost per
//! event is a single relaxed `AtomicU64` RMW — measured here both in
//! isolation (ns/op) and in situ (instrumented vs bare fold loop).
//!
//! Emits `BENCH_telemetry.json` (ns per counter/gauge/histogram op,
//! ingest overhead percent) so the overhead claim is machine-checkable
//! from this PR onward. `FEDHPC_BENCH_BUDGET_MS` shrinks the budget
//! for CI smoke runs.

use fedhpc::benchkit::{
    bench, budget_from_env, json_num_obj, print_table, write_json_report, BenchStats,
};
use fedhpc::telemetry::{Registry, ROUND_SECONDS_BUCKETS, STALENESS_BUCKETS};
use fedhpc::util::json::Value;
use fedhpc::util::rng::Rng;

/// Parameters folded per synthetic update — small enough that the
/// per-update instrumentation (3 atomic ops) is *visible* if it ever
/// grows a lock or allocation, large enough to stay realistic.
const P: usize = 65_536;
const OPS_PER_ITER: u64 = 1024;

/// The server's per-update fold, reduced to its memory traffic: one
/// pass accumulating a scaled delta, exactly what `fold_view` does for
/// a dense update.
fn fold_once(acc: &mut [f32], delta: &[f32], w: f32) -> f64 {
    let mut sum = 0.0f64;
    for (a, d) in acc.iter_mut().zip(delta) {
        *a += *d * w;
        sum += f64::from(*d);
    }
    sum
}

fn main() {
    let budget = budget_from_env(2000);
    let reg = Registry::new();
    let counter = reg.counter("bench_events_total", "bench counter");
    let gauge = reg.gauge("bench_value", "bench gauge");
    let hist_rounds = reg.histogram("bench_round_seconds", "bench histogram", ROUND_SECONDS_BUCKETS);
    let hist_stale = reg.histogram("bench_staleness", "bench histogram", STALENESS_BUCKETS);

    // ---- isolated op cost -------------------------------------- //
    let c_stats = bench("counter.inc x1024", budget, || {
        for _ in 0..OPS_PER_ITER {
            counter.inc();
        }
    });
    let g_stats = bench("gauge.set x1024", budget, || {
        for i in 0..OPS_PER_ITER {
            gauge.set(i);
        }
    });
    let h_stats = bench("histogram.observe x1024", budget, || {
        for i in 0..OPS_PER_ITER {
            hist_stale.observe((i % 40) as f64);
        }
    });
    let per_op = |s: &BenchStats| s.mean_ns / OPS_PER_ITER as f64;

    // ---- in-situ ingest overhead ------------------------------- //
    let mut rng = Rng::new(7);
    let delta: Vec<f32> = (0..P).map(|_| rng.normal() as f32 * 0.01).collect();
    let mut acc = vec![0.0f32; P];

    let bare = bench("ingest fold (bare)", budget, || {
        std::hint::black_box(fold_once(&mut acc, &delta, 0.25));
    });
    // per-update instrumentation exactly as orchestrator::server
    // applies it: bytes counter, update counter, staleness histogram
    let bytes_c = reg.counter("bench_ingest_bytes_total", "bench counter");
    let updates_c = reg.counter("bench_ingest_updates_total", "bench counter");
    let mut staleness = 0u64;
    let instrumented = bench("ingest fold (instrumented)", budget, || {
        std::hint::black_box(fold_once(&mut acc, &delta, 0.25));
        bytes_c.add((P * 4) as u64);
        updates_c.inc();
        staleness = (staleness + 1) % 8;
        hist_rounds.observe(0.12);
        hist_stale.observe(staleness as f64);
    });
    let overhead_pct = (instrumented.mean_ns / bare.mean_ns - 1.0) * 100.0;

    let stats = vec![c_stats, g_stats, h_stats, bare.clone(), instrumented.clone()];
    print_table("telemetry: per-op cost + instrumented ingest fold", &stats);
    println!(
        "\ncounter {:.1} ns/op, gauge {:.1} ns/op, histogram {:.1} ns/op",
        per_op(&stats[0]),
        per_op(&stats[1]),
        per_op(&stats[2]),
    );
    println!(
        "ingest fold: bare {:.0} ns, instrumented {:.0} ns -> {:+.3}% ({})",
        bare.mean_ns,
        instrumented.mean_ns,
        overhead_pct,
        if overhead_pct < 1.0 {
            "MEETS <1% target"
        } else {
            "misses <1% target"
        },
    );

    // sanity: the instrumented loop really recorded every event
    assert!(updates_c.get() > 0 && bytes_c.get() == updates_c.get() * (P * 4) as u64);

    let extras: Vec<(&str, Value)> = vec![
        (
            "per_op",
            json_num_obj(&[
                ("counter_inc_ns", per_op(&stats[0])),
                ("gauge_set_ns", per_op(&stats[1])),
                ("histogram_observe_ns", per_op(&stats[2])),
            ]),
        ),
        (
            "ingest_overhead",
            json_num_obj(&[
                ("params", P as f64),
                ("bare_fold_ns", bare.mean_ns),
                ("instrumented_fold_ns", instrumented.mean_ns),
                ("overhead_pct", overhead_pct),
                ("target_pct", 1.0),
            ]),
        ),
    ];
    write_json_report("BENCH_telemetry.json", "telemetry", &stats, &extras).unwrap();
}
