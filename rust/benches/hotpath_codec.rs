//! L3 hot path: compression codecs (paper §4.3). DESIGN.md §8 target:
//! q8 quantization > 1 GB/s.

use fedhpc::benchkit::{bench, budget_from_env, json_num_obj, print_table, write_json_report};
use fedhpc::compress::{compress, decompress, quantize, sparsify_topk, QuantBits};
use fedhpc::config::CompressionConfig;
use fedhpc::util::rng::Rng;

fn main() {
    let p = 1_000_000usize;
    let mut rng = Rng::new(0);
    let update: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let budget = budget_from_env(2000);
    let mut stats = Vec::new();

    stats.push(bench("quantize q8 1M", budget, || {
        std::hint::black_box(quantize(&update, QuantBits::B8));
    }));
    stats.push(bench("quantize q16 1M", budget, || {
        std::hint::black_box(quantize(&update, QuantBits::B16));
    }));
    stats.push(bench("sparsify top-10% 1M", budget, || {
        std::hint::black_box(sparsify_topk(&update, p / 10));
    }));
    stats.push(bench("sparsify top-25% 1M", budget, || {
        std::hint::black_box(sparsify_topk(&update, p / 4));
    }));
    let paper = CompressionConfig::PAPER;
    stats.push(bench("pipeline paper(top25+q8) 1M", budget, || {
        std::hint::black_box(compress(&update, &paper, 1));
    }));
    let enc = compress(&update, &paper, 1);
    stats.push(bench("decompress paper 1M", budget, || {
        std::hint::black_box(decompress(&enc, p).unwrap());
    }));

    print_table("codec hot path (Table 4 / §8 target: q8 > 1 GB/s)", &stats);
    let q8 = &stats[0];
    let gbps = q8.throughput(4.0 * p as f64) / 1e9;
    println!(
        "\nq8 throughput: {:.2} GB/s ({})",
        gbps,
        if gbps > 1.0 { "MEETS §8 target" } else { "misses §8 target" }
    );
    let extra = json_num_obj(&[("q8_gb_per_s", gbps), ("target_gb_per_s", 1.0)]);
    write_json_report(
        "BENCH_codec.json",
        "hotpath_codec",
        &stats,
        &[("section8", extra)],
    )
    .unwrap();
}
