//! Ingest hot path: what one arriving update costs the server.
//!
//! Baseline is the pre-PR path — `compress::decompress` materializes a
//! dense P-length vector, then the streaming engine folds all P
//! elements — so a top-25% sparse update cost the same as a dense one
//! and the compression win died at the server door. The fused path
//! (`DecodedView` → `fold_view`) folds straight from the encoded form:
//! O(nnz) work, zero dense materialization, and for pre-encoded wire
//! bytes not even an intermediate index/value `Vec`.
//!
//! The two paths are bit-identical (asserted below before timing, and
//! pinned by property test in `prop_invariants.rs`). Acceptance target
//! for this PR: ≥5× updates/sec at `CompressionConfig::PAPER` with 1M
//! params, and no regression on dense updates.
//!
//! Emits `BENCH_ingest.json` (updates/sec, bytes/update, speedup,
//! allocs avoided) so the repo's perf trajectory is machine-readable
//! from this PR onward. `FEDHPC_BENCH_BUDGET_MS` shrinks the budget
//! for CI smoke runs.

use fedhpc::benchkit::{
    bench, budget_from_env, json_num_obj, print_table, write_json_report, BenchStats,
};
use fedhpc::compress::{compress, decompress, DecodedView, Encoded};
use fedhpc::config::{Aggregation, CompressionConfig};
use fedhpc::network::pre_encode;
use fedhpc::orchestrator::strategy::registry::strategy_from_config;
use fedhpc::orchestrator::strategy::SgdServer;
use fedhpc::orchestrator::{AggInput, RoundAggregator, ViewInput};
use fedhpc::util::json::Value;
use fedhpc::util::rng::Rng;
use fedhpc::util::scratch::ScratchPool;
use std::sync::Arc;

const P: usize = 1_000_000;
const K: usize = 20;

struct Case {
    name: &'static str,
    cfg: CompressionConfig,
    /// Dense-vector allocations the baseline performs per update that
    /// the fused path does not (decode buffer, dequantize buffer).
    allocs_avoided: f64,
}

fn stats_of(client: u32) -> (u64, f32, f32) {
    (100 + (client as u64 * 37) % 400, 1.0, 0.01)
}

fn agg_input(client: u32, delta: Vec<f32>) -> AggInput {
    let (n_samples, train_loss, update_var) = stats_of(client);
    AggInput {
        client,
        delta,
        n_samples,
        train_loss,
        update_var,
    }
}

fn view_input<'a>(client: u32, view: &'a DecodedView<'a>) -> ViewInput<'a> {
    let (n_samples, train_loss, update_var) = stats_of(client);
    ViewInput {
        client,
        view,
        n_samples,
        train_loss,
        update_var,
    }
}

/// One collection phase over `encs` through the baseline
/// densify-then-fold path; returns the finalized model.
fn round_baseline(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    global: &[f32],
    encs: &[Encoded],
) -> Vec<f32> {
    let mut agg = RoundAggregator::new(strategy.clone(), P);
    for (c, enc) in encs.iter().enumerate() {
        let dense = decompress(enc, P).unwrap();
        agg.fold(&agg_input(c as u32, dense)).unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

/// The same collection phase through the fused decode→fold ingest.
fn round_fused(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    pool: &Arc<ScratchPool>,
    global: &[f32],
    encs: &[Encoded],
) -> Vec<f32> {
    let mut agg = RoundAggregator::with_pool(strategy.clone(), P, pool.clone());
    for (c, enc) in encs.iter().enumerate() {
        let view = DecodedView::of(enc, P).unwrap();
        agg.fold_view(&view_input(c as u32, &view)).unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

fn main() {
    let budget = budget_from_env(3000);
    let strategy = strategy_from_config(&Aggregation::FedAvg);
    let pool = Arc::new(ScratchPool::new());
    let mut rng = Rng::new(42);
    let global: Vec<f32> = (0..P).map(|_| rng.normal() as f32).collect();

    let cases = [
        Case {
            name: "paper(top25+q8)",
            cfg: CompressionConfig::PAPER,
            allocs_avoided: 2.0, // dense decode buffer + dequantize buffer
        },
        Case {
            name: "sparse(top25,f32)",
            cfg: CompressionConfig {
                quant_bits: 32,
                topk_frac: 0.25,
                dropout_keep: 1.0,
            },
            allocs_avoided: 1.0,
        },
        Case {
            name: "dense(none)",
            cfg: CompressionConfig::NONE,
            allocs_avoided: 1.0, // decompress clones the dense vector
        },
    ];

    let mut stats: Vec<BenchStats> = Vec::new();
    let mut extra: Vec<(String, Value)> = Vec::new();
    let mut paper_speedup = None;
    let mut dense_speedup = None;

    for case in &cases {
        // K distinct client updates, compressed once up front — ingest
        // starts at the decoded wire message, like the server's
        let encs: Vec<Encoded> = (0..K)
            .map(|c| {
                let mut r = Rng::new(1000 + c as u64);
                let upd: Vec<f32> = (0..P).map(|_| r.normal() as f32 * 0.01).collect();
                compress(&upd, &case.cfg, c as u64)
            })
            .collect();
        // the fused path must be pinned bit-identical before we time it
        let a = round_baseline(&strategy, &global, &encs);
        let b = round_fused(&strategy, &pool, &global, &encs);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}: paths diverged", case.name);
        }
        // and the borrowed wire-bytes path must agree too
        let pre: Vec<Encoded> = encs
            .iter()
            .map(|e| Encoded::PreEncoded(pre_encode(e)))
            .collect();
        let c = round_fused(&strategy, &pool, &global, &pre);
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}: wire path diverged", case.name);
        }

        let wire_bytes = encs[0].wire_bytes() as f64;
        let base = bench(&format!("densify+fold {}", case.name), budget, || {
            std::hint::black_box(round_baseline(&strategy, &global, &encs).len());
        });
        let fused = bench(&format!("fused fold   {}", case.name), budget, || {
            std::hint::black_box(round_fused(&strategy, &pool, &global, &encs).len());
        });
        let wire = bench(&format!("fused wire   {}", case.name), budget, || {
            std::hint::black_box(round_fused(&strategy, &pool, &global, &pre).len());
        });

        let ups = |s: &BenchStats| K as f64 / (s.mean_ns / 1e9);
        let speedup = ups(&fused) / ups(&base);
        println!(
            "{}: baseline {:.0} updates/s, fused {:.0} updates/s ({:.2}x), wire-bytes {:.0} updates/s",
            case.name,
            ups(&base),
            ups(&fused),
            speedup,
            ups(&wire),
        );
        extra.push((
            case.name.to_string(),
            json_num_obj(&[
                ("params", P as f64),
                ("updates_per_round", K as f64),
                ("bytes_per_update", wire_bytes),
                ("baseline_updates_per_sec", ups(&base)),
                ("fused_updates_per_sec", ups(&fused)),
                ("wire_updates_per_sec", ups(&wire)),
                ("speedup", speedup),
                ("allocs_avoided_per_update", case.allocs_avoided),
            ]),
        ));
        match case.name {
            "paper(top25+q8)" => paper_speedup = Some(speedup),
            "dense(none)" => dense_speedup = Some(speedup),
            _ => {}
        }
        stats.push(base);
        stats.push(fused);
        stats.push(wire);
    }

    print_table(
        "update ingest (densify-then-fold baseline vs fused decode→fold), K=20 rounds of 1M params",
        &stats,
    );
    let paper = paper_speedup.unwrap();
    let dense = dense_speedup.unwrap();
    println!(
        "\nPAPER config: {:.2}x updates/sec ({}); dense: {:.2}x ({})",
        paper,
        if paper >= 5.0 { "MEETS >=5x target" } else { "misses >=5x target" },
        dense,
        if dense >= 0.95 { "no regression" } else { "REGRESSION" },
    );

    let extras: Vec<(&str, Value)> = extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    write_json_report("BENCH_ingest.json", "hotpath_ingest", &stats, &extras).unwrap();
}
