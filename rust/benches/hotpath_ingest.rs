//! Ingest hot path: what one arriving update costs the server.
//!
//! Baseline is the pre-PR path — `compress::decompress` materializes a
//! dense P-length vector, then the streaming engine folds all P
//! elements — so a top-25% sparse update cost the same as a dense one
//! and the compression win died at the server door. The fused path
//! (`DecodedView` → `fold_view`) folds straight from the encoded form:
//! O(nnz) work, zero dense materialization, and for pre-encoded wire
//! bytes not even an intermediate index/value `Vec`.
//!
//! The two paths are bit-identical (asserted below before timing, and
//! pinned by property test in `prop_invariants.rs`). Acceptance target
//! for this PR: ≥5× updates/sec at `CompressionConfig::PAPER` with 1M
//! params, and no regression on dense updates.
//!
//! Emits `BENCH_ingest.json` (updates/sec, bytes/update, speedup,
//! allocs avoided) so the repo's perf trajectory is machine-readable
//! from this PR onward. `FEDHPC_BENCH_BUDGET_MS` shrinks the budget
//! for CI smoke runs.
//!
//! The shard-scaling sweep (ISSUE 8) times the same round through the
//! persistent shard-worker pool at 1/2/4/8 workers: 1M params × 200
//! concurrent arrivals, bit-identity to the serial fold asserted at
//! every worker count before any timing. Target: ≥2.5× serial
//! updates/sec at 4 workers.

use fedhpc::benchkit::{
    bench, budget_from_env, json_num_obj, print_table, write_json_report, BenchStats,
};
use fedhpc::compress::{compress, decompress, DecodedView, Encoded, SharedDecoded};
use fedhpc::config::{Aggregation, CompressionConfig};
use fedhpc::network::pre_encode;
use fedhpc::orchestrator::strategy::registry::strategy_from_config;
use fedhpc::orchestrator::strategy::SgdServer;
use fedhpc::orchestrator::{
    default_ingest_shards, AggInput, RoundAggregator, SharedInput, ViewInput,
};
use fedhpc::util::json::Value;
use fedhpc::util::parallel::ShardPool;
use fedhpc::util::rng::Rng;
use fedhpc::util::scratch::ScratchPool;
use std::sync::Arc;

const P: usize = 1_000_000;
const K: usize = 20;
/// Concurrent arrivals per round for the shard-scaling sweep: 200
/// updates over `K` distinct payloads (`Arc`-shared, like the server's
/// owned ingest), so the sweep measures fold throughput, not codec
/// memory.
const CONC: usize = 200;

struct Case {
    name: &'static str,
    cfg: CompressionConfig,
    /// Dense-vector allocations the baseline performs per update that
    /// the fused path does not (decode buffer, dequantize buffer).
    allocs_avoided: f64,
}

fn stats_of(client: u32) -> (u64, f32, f32) {
    (100 + (client as u64 * 37) % 400, 1.0, 0.01)
}

fn agg_input(client: u32, delta: Vec<f32>) -> AggInput {
    let (n_samples, train_loss, update_var) = stats_of(client);
    AggInput {
        client,
        delta,
        n_samples,
        train_loss,
        update_var,
    }
}

fn view_input<'a>(client: u32, view: &'a DecodedView<'a>) -> ViewInput<'a> {
    let (n_samples, train_loss, update_var) = stats_of(client);
    ViewInput {
        client,
        view,
        n_samples,
        train_loss,
        update_var,
    }
}

/// One collection phase over `encs` through the baseline
/// densify-then-fold path; returns the finalized model.
fn round_baseline(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    global: &[f32],
    encs: &[Encoded],
) -> Vec<f32> {
    let mut agg = RoundAggregator::new(strategy.clone(), P);
    for (c, enc) in encs.iter().enumerate() {
        let dense = decompress(enc, P).unwrap();
        agg.fold(&agg_input(c as u32, dense)).unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

/// The same collection phase through the fused decode→fold ingest.
fn round_fused(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    pool: &Arc<ScratchPool>,
    global: &[f32],
    encs: &[Encoded],
) -> Vec<f32> {
    let mut agg = RoundAggregator::with_pool(strategy.clone(), P, pool.clone());
    for (c, enc) in encs.iter().enumerate() {
        let view = DecodedView::of(enc, P).unwrap();
        agg.fold_view(&view_input(c as u32, &view)).unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

/// `CONC` arrivals through the serial streaming fold (the reference
/// the sharded pool must reproduce bit-for-bit).
fn round_serial_conc(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    global: &[f32],
    encs: &[Encoded],
) -> Vec<f32> {
    let mut agg = RoundAggregator::new(strategy.clone(), P);
    for c in 0..CONC {
        let view = DecodedView::of(&encs[c % encs.len()], P).unwrap();
        agg.fold_view(&view_input(c as u32, &view)).unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

/// The same `CONC` arrivals enqueued into a persistent shard-worker
/// pool: workers fold disjoint spans concurrently, finalize barriers
/// and merges in shard order.
fn round_sharded(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    scratch: &Arc<ScratchPool>,
    pool: &Arc<ShardPool>,
    global: &[f32],
    payloads: &[Arc<SharedDecoded>],
) -> Vec<f32> {
    let mut agg = RoundAggregator::with_ingest(
        strategy.clone(),
        P,
        scratch.clone(),
        Some(pool.clone()),
    );
    assert!(agg.ingest_sharded(), "FedAvg must take the sharded path");
    for c in 0..CONC {
        let (n_samples, train_loss, update_var) = stats_of(c as u32);
        agg.fold_shared(&SharedInput {
            client: c as u32,
            payload: payloads[c % payloads.len()].clone(),
            n_samples,
            train_loss,
            update_var,
        })
        .unwrap();
    }
    agg.finalize(global, &mut SgdServer).unwrap().new_params
}

/// Shard-scaling sweep (ISSUE 8 acceptance): 1M params × `CONC`
/// concurrent updates at 1/2/4/8 workers vs the serial reference.
/// Bit-identity is asserted before any timing; per-worker-count
/// throughput lands in `BENCH_ingest.json`.
fn shard_scaling_sweep(
    strategy: &Arc<dyn fedhpc::orchestrator::AggStrategy>,
    scratch: &Arc<ScratchPool>,
    global: &[f32],
    budget: std::time::Duration,
    stats: &mut Vec<BenchStats>,
    extra: &mut Vec<(String, Value)>,
) {
    let encs: Vec<Encoded> = (0..K)
        .map(|c| {
            let mut r = Rng::new(5000 + c as u64);
            let upd: Vec<f32> = (0..P).map(|_| r.normal() as f32 * 0.01).collect();
            compress(&upd, &CompressionConfig::PAPER, c as u64)
        })
        .collect();
    let payloads: Vec<Arc<SharedDecoded>> = encs
        .iter()
        .map(|e| Arc::new(SharedDecoded::new(Arc::new(e.clone()), P).unwrap()))
        .collect();

    let reference = round_serial_conc(strategy, global, &encs);
    let n_shards = default_ingest_shards(P);
    let mut serial_ups = None;
    let mut sweep = Vec::new();
    let serial = bench(&format!("ingest serial      ({CONC} upd)"), budget, || {
        std::hint::black_box(round_serial_conc(strategy, global, &encs).len());
    });
    let ups = |s: &BenchStats| CONC as f64 / (s.mean_ns / 1e9);
    serial_ups.replace(ups(&serial));
    stats.push(serial);

    for workers in [1usize, 2, 4, 8] {
        let pool = Arc::new(ShardPool::new(workers, n_shards));
        // bit-identity before timing: the pool must reproduce the
        // serial fold exactly, at every worker count
        let got = round_sharded(strategy, scratch, &pool, global, &payloads);
        for (x, y) in reference.iter().zip(&got) {
            assert_eq!(x.to_bits(), y.to_bits(), "sharded({workers}w) diverged");
        }
        let s = bench(
            &format!("ingest sharded {workers}w/{n_shards}s ({CONC} upd)"),
            budget,
            || {
                std::hint::black_box(
                    round_sharded(strategy, scratch, &pool, global, &payloads).len(),
                );
            },
        );
        // the whole sweep reuses each pool's threads: per-fold spawns
        // would show up here as threads_spawned > workers
        assert_eq!(
            pool.threads_spawned(),
            workers,
            "pool must spawn each worker exactly once"
        );
        sweep.push((workers, ups(&s)));
        stats.push(s);
    }

    let serial_ups = serial_ups.unwrap();
    let mut fields: Vec<(String, f64)> = vec![
        ("params".into(), P as f64),
        ("concurrent_updates".into(), CONC as f64),
        ("shards".into(), n_shards as f64),
        ("serial_updates_per_sec".into(), serial_ups),
    ];
    for &(w, u) in &sweep {
        fields.push((format!("sharded_{w}w_updates_per_sec"), u));
        fields.push((format!("sharded_{w}w_speedup"), u / serial_ups));
    }
    let borrowed: Vec<(&str, f64)> = fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    extra.push(("shard_scaling".to_string(), json_num_obj(&borrowed)));

    let at4 = sweep
        .iter()
        .find(|&&(w, _)| w == 4)
        .map(|&(_, u)| u / serial_ups)
        .unwrap();
    let worst = sweep
        .iter()
        .map(|&(_, u)| u / serial_ups)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nshard scaling: serial {:.0} updates/s; 4 workers {:.2}x ({}); worst worker count {:.2}x ({})",
        serial_ups,
        at4,
        if at4 >= 2.5 { "MEETS >=2.5x target" } else { "misses >=2.5x target" },
        worst,
        if worst >= 0.9 { "multi-shard keeps up with serial" } else { "SLOWER than serial" },
    );
}

fn main() {
    let budget = budget_from_env(3000);
    let strategy = strategy_from_config(&Aggregation::FedAvg);
    let pool = Arc::new(ScratchPool::new());
    let mut rng = Rng::new(42);
    let global: Vec<f32> = (0..P).map(|_| rng.normal() as f32).collect();

    let cases = [
        Case {
            name: "paper(top25+q8)",
            cfg: CompressionConfig::PAPER,
            allocs_avoided: 2.0, // dense decode buffer + dequantize buffer
        },
        Case {
            name: "sparse(top25,f32)",
            cfg: CompressionConfig {
                quant_bits: 32,
                topk_frac: 0.25,
                dropout_keep: 1.0,
            },
            allocs_avoided: 1.0,
        },
        Case {
            name: "dense(none)",
            cfg: CompressionConfig::NONE,
            allocs_avoided: 1.0, // decompress clones the dense vector
        },
    ];

    let mut stats: Vec<BenchStats> = Vec::new();
    let mut extra: Vec<(String, Value)> = Vec::new();
    let mut paper_speedup = None;
    let mut dense_speedup = None;

    for case in &cases {
        // K distinct client updates, compressed once up front — ingest
        // starts at the decoded wire message, like the server's
        let encs: Vec<Encoded> = (0..K)
            .map(|c| {
                let mut r = Rng::new(1000 + c as u64);
                let upd: Vec<f32> = (0..P).map(|_| r.normal() as f32 * 0.01).collect();
                compress(&upd, &case.cfg, c as u64)
            })
            .collect();
        // the fused path must be pinned bit-identical before we time it
        let a = round_baseline(&strategy, &global, &encs);
        let b = round_fused(&strategy, &pool, &global, &encs);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}: paths diverged", case.name);
        }
        // and the borrowed wire-bytes path must agree too
        let pre: Vec<Encoded> = encs
            .iter()
            .map(|e| Encoded::PreEncoded(pre_encode(e)))
            .collect();
        let c = round_fused(&strategy, &pool, &global, &pre);
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}: wire path diverged", case.name);
        }

        let wire_bytes = encs[0].wire_bytes() as f64;
        let base = bench(&format!("densify+fold {}", case.name), budget, || {
            std::hint::black_box(round_baseline(&strategy, &global, &encs).len());
        });
        let fused = bench(&format!("fused fold   {}", case.name), budget, || {
            std::hint::black_box(round_fused(&strategy, &pool, &global, &encs).len());
        });
        let wire = bench(&format!("fused wire   {}", case.name), budget, || {
            std::hint::black_box(round_fused(&strategy, &pool, &global, &pre).len());
        });

        let ups = |s: &BenchStats| K as f64 / (s.mean_ns / 1e9);
        let speedup = ups(&fused) / ups(&base);
        println!(
            "{}: baseline {:.0} updates/s, fused {:.0} updates/s ({:.2}x), wire-bytes {:.0} updates/s",
            case.name,
            ups(&base),
            ups(&fused),
            speedup,
            ups(&wire),
        );
        extra.push((
            case.name.to_string(),
            json_num_obj(&[
                ("params", P as f64),
                ("updates_per_round", K as f64),
                ("bytes_per_update", wire_bytes),
                ("baseline_updates_per_sec", ups(&base)),
                ("fused_updates_per_sec", ups(&fused)),
                ("wire_updates_per_sec", ups(&wire)),
                ("speedup", speedup),
                ("allocs_avoided_per_update", case.allocs_avoided),
            ]),
        ));
        match case.name {
            "paper(top25+q8)" => paper_speedup = Some(speedup),
            "dense(none)" => dense_speedup = Some(speedup),
            _ => {}
        }
        stats.push(base);
        stats.push(fused);
        stats.push(wire);
    }

    shard_scaling_sweep(&strategy, &pool, &global, budget, &mut stats, &mut extra);

    print_table(
        "update ingest (densify-then-fold baseline vs fused decode→fold), K=20 rounds of 1M params",
        &stats,
    );
    let paper = paper_speedup.unwrap();
    let dense = dense_speedup.unwrap();
    println!(
        "\nPAPER config: {:.2}x updates/sec ({}); dense: {:.2}x ({})",
        paper,
        if paper >= 5.0 { "MEETS >=5x target" } else { "misses >=5x target" },
        dense,
        if dense >= 0.95 { "no regression" } else { "REGRESSION" },
    );

    let extras: Vec<(&str, Value)> = extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    write_json_report("BENCH_ingest.json", "hotpath_ingest", &stats, &extras).unwrap();
}
