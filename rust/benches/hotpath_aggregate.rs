//! L3 hot path: aggregation of K client updates into the global model.
//! DESIGN.md §8 target: 60 × 1M-param updates in < 50 ms.

use fedhpc::benchkit::{bench, budget_from_env, json_num_obj, print_table, write_json_report};
use fedhpc::config::{Aggregation, WeightScheme};
use fedhpc::orchestrator::{aggregate, AggInput};
use fedhpc::util::rng::Rng;

fn inputs(k: usize, p: usize, seed: u64) -> (Vec<f32>, Vec<AggInput>) {
    let mut rng = Rng::new(seed);
    let global: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let inputs = (0..k)
        .map(|c| AggInput {
            client: c as u32,
            delta: (0..p).map(|_| rng.normal() as f32 * 0.01).collect(),
            n_samples: 100 + (c as u64 * 37) % 400,
            train_loss: 1.0 + c as f32 * 0.01,
            update_var: 0.01,
        })
        .collect();
    (global, inputs)
}

fn main() {
    let budget = budget_from_env(2000);
    let mut stats = Vec::new();
    for (k, p) in [(20usize, 250_000usize), (60, 250_000), (20, 1_000_000), (60, 1_000_000)] {
        let (global, ins) = inputs(k, p, 42);
        stats.push(bench(
            &format!("fedavg k={k} P={}", p / 1000),
            budget,
            || {
                let out = aggregate(&global, &ins, Aggregation::FedAvg).unwrap();
                std::hint::black_box(out.new_params.len());
            },
        ));
    }
    let (global, ins) = inputs(60, 1_000_000, 7);
    for (name, strat) in [
        ("weighted:inverse-loss k=60 P=1000", Aggregation::Weighted(WeightScheme::InverseLoss)),
        (
            "weighted:inverse-var  k=60 P=1000",
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ),
        // buffered order statistics: per-coordinate sort of k values —
        // the price of robustness vs the streaming weighted fold
        (
            "trimmed-mean:0.1      k=60 P=1000",
            Aggregation::TrimmedMean { trim_frac: 0.1 },
        ),
        ("coordinate-median     k=60 P=1000", Aggregation::CoordinateMedian),
    ] {
        stats.push(bench(name, budget, || {
            let out = aggregate(&global, &ins, strat).unwrap();
            std::hint::black_box(out.new_params.len());
        }));
    }
    print_table("aggregation hot path (Table 3 / §8 target: 60×1M < 50 ms)", &stats);
    let target = &stats[3];
    println!(
        "\n60 clients × 1M params: {:.1} ms mean ({})",
        target.mean_ms(),
        if target.mean_ms() < 50.0 { "MEETS §8 target" } else { "misses §8 target" }
    );
    let extra = json_num_obj(&[
        ("fedavg_60x1m_ms", target.mean_ms()),
        ("target_ms", 50.0),
    ]);
    write_json_report(
        "BENCH_aggregate.json",
        "hotpath_aggregate",
        &stats,
        &[("section8", extra)],
    )
    .unwrap();
}
