//! Table 4 reproduction as a bench target: per-round communication
//! volume with vs without compression, on real model-sized updates,
//! plus wire-encode throughput of the full Update message.

use fedhpc::benchkit::{bench, print_table};
use fedhpc::compress::{compress, CompressionStats, Encoded};
use fedhpc::config::CompressionConfig;
use fedhpc::network::{Msg, UpdateStats};
use fedhpc::util::{human_bytes, rng::Rng};
use std::time::Duration;

fn main() {
    // Paper Table 4 shape: N params such that dense ≈ 45 MB — the
    // paper's per-round payload — then the compressed counterpart.
    let p = 45 * 1024 * 1024 / 4;
    let mut rng = Rng::new(4);
    let update: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();

    println!("=== Table 4 (per-client payload) ===");
    println!("{:>22} {:>14} {:>10}", "codec", "payload", "ratio");
    for (name, cfg) in [
        ("no compression", CompressionConfig::NONE),
        ("paper (top25% + q8)", CompressionConfig::PAPER),
    ] {
        let enc = compress(&update, &cfg, 1);
        let stats = CompressionStats::of(&enc);
        println!(
            "{:>22} {:>14} {:>9.0}%",
            name,
            human_bytes(stats.wire_bytes),
            stats.ratio() * 100.0
        );
    }
    println!("(paper: ~45 MB → ~15 MB, ≈65% reduction)");

    let budget = Duration::from_secs(2);
    let enc_none = Encoded::Dense(update.clone());
    let enc_paper = compress(&update, &CompressionConfig::PAPER, 1);
    let stats_of = |delta: Encoded| Msg::Update {
        round: 1,
        client: 0,
        base_version: 1,
        delta,
        stats: UpdateStats {
            n_samples: 512,
            train_loss: 1.0,
            steps: 80,
            compute_ms: 100.0,
            update_var: 0.01,
        },
    };
    let m_none = stats_of(enc_none);
    let m_paper = stats_of(enc_paper);
    let mut stats = Vec::new();
    stats.push(bench("wire-encode dense 45MB", budget, || {
        std::hint::black_box(m_none.encode().len());
    }));
    stats.push(bench("wire-encode paper-compressed", budget, || {
        std::hint::black_box(m_paper.encode().len());
    }));
    stats.push(bench("compress paper 45MB", budget, || {
        std::hint::black_box(compress(&update, &CompressionConfig::PAPER, 1));
    }));
    print_table("Table 4 wire path", &stats);
}
