//! Orchestrator selection + registry benchmarks: adaptive selection
//! must stay negligible next to round compute even at 1000s of clients
//! (paper §3.1 scalability objective).

use fedhpc::benchkit::{bench, print_table};
use fedhpc::config::{SelectionConfig, SelectionPolicy};
use fedhpc::network::ClientProfile;
use fedhpc::orchestrator::{select_clients, ClientRegistry};
use fedhpc::util::rng::Rng;
use std::time::Duration;

fn registry(n: u32) -> (ClientRegistry, Vec<u32>) {
    let mut reg = ClientRegistry::new();
    let mut rng = Rng::new(0);
    for i in 0..n {
        reg.register(
            i,
            ClientProfile {
                speed_factor: 0.1 + rng.f64(),
                mem_gb: 16.0,
                link_bw: 1e8 + rng.f64() * 1e9,
                n_samples: 100,
                bench_step_ms: 5.0 + rng.f64() * 100.0,
            },
        );
        for r in 0..5 {
            reg.report_success(i, r, 50.0 + rng.f64() * 500.0);
        }
    }
    (reg, (0..n).collect())
}

fn main() {
    let budget = Duration::from_secs(2);
    let mut stats = Vec::new();
    for n in [60u32, 1_000, 10_000] {
        let (mut reg, avail) = registry(n);
        let k = (n / 3) as usize;
        let cfg = SelectionConfig {
            policy: SelectionPolicy::Adaptive {
                explore_frac: 0.2,
                exclude_factor: 2.5,
            },
            clients_per_round: k,
        };
        let mut rng = Rng::new(1);
        let mut round = 0;
        stats.push(bench(&format!("adaptive n={n} k={k}"), budget, || {
            round += 1;
            std::hint::black_box(select_clients(&mut reg, &avail, &cfg, round, &mut rng));
        }));
        let cfg_rand = SelectionConfig {
            policy: SelectionPolicy::Random,
            clients_per_round: k,
        };
        let (mut reg2, avail2) = registry(n);
        stats.push(bench(&format!("random   n={n} k={k}"), budget, || {
            std::hint::black_box(select_clients(&mut reg2, &avail2, &cfg_rand, 0, &mut rng));
        }));
    }
    print_table("client selection (paper §4.1; scale target: 10k clients)", &stats);
}
