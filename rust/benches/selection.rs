//! Cohort-planning benchmarks: planning must stay negligible next to
//! round compute even at 1000s of clients (paper §3.1 scalability
//! objective). Compares every registered planner at a realistic fleet
//! shape (1k available / 100 selected) plus a 10k stress point, and
//! emits a machine-readable `BENCH_selection.json` via benchkit so the
//! perf trajectory is trackable across PRs (`FEDHPC_BENCH_BUDGET_MS`
//! shrinks the budget for CI smoke runs).

use fedhpc::benchkit::{bench, budget_from_env, json_num_obj, print_table, write_json_report};
use fedhpc::config::CompressionConfig;
use fedhpc::network::ClientProfile;
use fedhpc::orchestrator::planner::planner_by_name;
use fedhpc::orchestrator::{ClientRegistry, DispatchPlan, PlanContext};
use fedhpc::util::rng::Rng;

/// Registered planner specs exercised by this bench.
const PLANNERS: &[&str] = &["random", "adaptive", "tiered:4", "deadline:2000"];

fn registry(n: u32) -> (ClientRegistry, Vec<u32>) {
    let mut reg = ClientRegistry::new();
    let mut rng = Rng::new(0);
    for i in 0..n {
        reg.register(
            i,
            ClientProfile {
                speed_factor: 0.1 + rng.f64(),
                mem_gb: 16.0,
                link_bw: 1e8 + rng.f64() * 1e9,
                n_samples: 100,
                bench_step_ms: 5.0 + rng.f64() * 100.0,
            },
        );
        for r in 0..5 {
            reg.report_success(i, r, 50.0 + rng.f64() * 500.0);
        }
    }
    (reg, (0..n).collect())
}

fn defaults() -> DispatchPlan {
    DispatchPlan {
        deadline_ms: 60_000,
        local_epochs: 5,
        compression: CompressionConfig::PAPER,
    }
}

fn main() {
    let budget = budget_from_env(2_000);
    let mut stats = Vec::new();
    // realistic cohort shape first (1k fleet, 10% cohort), then the
    // 10k-client scale target
    for (n, k) in [(1_000u32, 100usize), (10_000, 1_000)] {
        for spec in PLANNERS {
            let (mut reg, avail) = registry(n);
            let mut planner = planner_by_name(spec).unwrap();
            let mut rng = Rng::new(1);
            let mut round = 0u32;
            stats.push(bench(&format!("{spec:<14} n={n} k={k}"), budget, || {
                round += 1;
                let ctx = PlanContext {
                    round,
                    k,
                    defaults: defaults(),
                };
                std::hint::black_box(planner.plan(&mut reg, &avail, &ctx, &mut rng));
            }));
        }
    }
    print_table("cohort planning (paper §4.1; scale target: 10k clients)", &stats);
    let extra = json_num_obj(&[
        ("fleet_small", 1_000.0),
        ("cohort_small", 100.0),
        ("fleet_large", 10_000.0),
        ("cohort_large", 1_000.0),
        ("planners", PLANNERS.len() as f64),
    ]);
    write_json_report("BENCH_selection.json", "selection", &stats, &[("shape", extra)])
        .expect("writing BENCH_selection.json");
}
