//! Hierarchical aggregation plane bench: the cross-facility wire-byte
//! claim at scale. A 1000-client / 10-site virtual-time run is compared
//! against the equivalent flat deployment: the tree must move at least
//! 5× fewer cross-facility bytes per direction (it lands near 100×,
//! one site report standing in for ~100 client updates). Emits
//! `BENCH_hierarchy.json` via benchkit (`FEDHPC_BENCH_BUDGET_MS`
//! shrinks the timing budget for CI smoke runs; the byte comparison
//! always runs in full).

use fedhpc::benchkit::{bench, budget_from_env, json_num_obj, print_table, write_json_report};
use fedhpc::config::presets::quickstart;
use fedhpc::config::{ExperimentConfig, GroupingPolicy, Partition};
use fedhpc::experiments::{run_sim, SimTiming};

const CLIENTS: usize = 1_000;
const SITES: usize = 10;
const ROUNDS: usize = 2;

fn cfg_for(n_clients: usize, sites: Option<usize>, rounds: usize) -> ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = match sites {
        Some(s) => format!("bench_hierarchy_{n_clients}c_{s}s"),
        None => format!("bench_hierarchy_{n_clients}c_flat"),
    };
    cfg.seed = 7;
    cfg.mock_runtime = true;
    let q = n_clients / 4;
    cfg.cluster.nodes = vec![
        ("p3.2xlarge".into(), q),
        ("t3.large".into(), q),
        ("hpc-rtx6000".into(), q),
        ("hpc-cpu".into(), n_clients - 3 * q),
    ];
    // every client participates every round: the flat baseline pays
    // O(clients) cross-facility traffic, the tree O(sites)
    cfg.selection.clients_per_round = n_clients;
    cfg.train.rounds = rounds;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 16;
    cfg.data.eval_samples = 32;
    cfg.data.partition = Partition::Iid;
    if let Some(s) = sites {
        cfg.hierarchy.grouping = GroupingPolicy::Site { sites: s };
    }
    cfg
}

fn total_bytes(cfg: &ExperimentConfig) -> (u64, u64) {
    let sim = run_sim(cfg, &SimTiming::default(), false).expect("sim run");
    let down = sim.report.rounds.iter().map(|r| r.bytes_down).sum();
    let up = sim.report.rounds.iter().map(|r| r.bytes_up).sum();
    (down, up)
}

fn main() {
    // the acceptance claim, measured in full regardless of budget
    let (down_flat, up_flat) = total_bytes(&cfg_for(CLIENTS, None, ROUNDS));
    let (down_tree, up_tree) = total_bytes(&cfg_for(CLIENTS, Some(SITES), ROUNDS));
    let red_up = up_flat as f64 / up_tree.max(1) as f64;
    let red_down = down_flat as f64 / down_tree.max(1) as f64;
    println!("=== cross-facility wire bytes, {CLIENTS} clients / {SITES} sites, {ROUNDS} rounds ===");
    println!("{:>10} {:>14} {:>14} {:>9}", "direction", "flat", "tree", "ratio");
    println!("{:>10} {:>14} {:>14} {:>8.1}x", "up", up_flat, up_tree, red_up);
    println!("{:>10} {:>14} {:>14} {:>8.1}x", "down", down_flat, down_tree, red_down);
    assert!(
        red_up >= 5.0 && red_down >= 5.0,
        "hierarchy must cut cross-facility bytes ≥5× (got up {red_up:.1}x, down {red_down:.1}x)"
    );

    // simulator cost of the tree plane (smaller fleet so the timing
    // loop stays cheap under CI budgets)
    let budget = budget_from_env(2_000);
    let flat_small = cfg_for(200, None, 2);
    let tree_small = cfg_for(200, Some(SITES), 2);
    let mut stats = Vec::new();
    for (tag, cfg) in [("flat", &flat_small), ("two-tier", &tree_small)] {
        stats.push(bench(
            &format!("run_sim {tag} 200 clients x 2 rounds"),
            budget,
            || {
                std::hint::black_box(run_sim(cfg, &SimTiming::default(), false).unwrap());
            },
        ));
    }
    print_table("two-tier sim throughput", &stats);

    let shape = json_num_obj(&[
        ("clients", CLIENTS as f64),
        ("sites", SITES as f64),
        ("rounds", ROUNDS as f64),
        ("bytes_up_flat", up_flat as f64),
        ("bytes_up_tree", up_tree as f64),
        ("bytes_down_flat", down_flat as f64),
        ("bytes_down_tree", down_tree as f64),
        ("reduction_up", red_up),
        ("reduction_down", red_down),
    ]);
    write_json_report("BENCH_hierarchy.json", "hierarchy", &stats, &[("shape", shape)])
        .expect("writing BENCH_hierarchy.json");
}
