//! Collection-phase hot path: the pre-streaming orchestrator (buffer
//! all k decoded deltas, then one block-major batch aggregate — the
//! kernel reproduced below verbatim) vs streaming (fold each delta
//! into the O(P) accumulator the moment it arrives, free it, normalize
//! once at the end).
//!
//! Reports round wall-time plus a bytes-held proxy for collection-phase
//! peak memory: the buffered path keeps k decoded f32 deltas alive at
//! once (O(k·P)); the streaming path keeps one decoded delta plus the
//! f64 accumulator (O(P)). Streaming pays more accumulator bandwidth
//! per round (~k·16P vs ~k·4P) — this bench makes that trade visible
//! instead of implicit.

use fedhpc::benchkit::{
    bench, budget_from_env, fmt_ns, json_num_obj, print_table, write_json_report, BenchStats,
};
use fedhpc::config::Aggregation;
use fedhpc::orchestrator::strategy::registry::strategy_from_config;
use fedhpc::orchestrator::strategy::SgdServer;
use fedhpc::orchestrator::{AggInput, RoundAggregator};
use fedhpc::util::parallel::par_chunks_mut;
use fedhpc::util::rng::Rng;

/// The pre-streaming batch kernel (block-major, L1-resident f64
/// accumulator block), kept here as the honest baseline: this is the
/// exact shape `orchestrator::aggregate` had before the streaming
/// refactor.
fn blocked_batch_aggregate(global: &[f32], inputs: &[AggInput]) -> Vec<f32> {
    const BLOCK: usize = 4096;
    let raw: Vec<f64> = inputs.iter().map(|i| i.n_samples.max(1) as f64).collect();
    let total: f64 = raw.iter().sum();
    let wn: Vec<f64> = raw.iter().map(|&w| w / total).collect();
    let mut new_params = vec![0f32; global.len()];
    par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
        let mut acc = [0f64; BLOCK];
        let mut start = 0;
        while start < chunk.len() {
            let len = BLOCK.min(chunk.len() - start);
            let base = offset + start;
            acc[..len].fill(0.0);
            for (input, &w) in inputs.iter().zip(&wn) {
                let d = &input.delta[base..base + len];
                for (a, &x) in acc[..len].iter_mut().zip(d) {
                    *a += w * x as f64;
                }
            }
            let g = &global[base..base + len];
            for ((out, &a), &gv) in chunk[start..start + len].iter_mut().zip(&acc[..len]).zip(g) {
                *out = (gv as f64 + a) as f32;
            }
            start += len;
        }
    });
    new_params
}

fn template_delta(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..p).map(|_| rng.normal() as f32 * 0.01).collect()
}

fn input(client: u32, delta: Vec<f32>) -> AggInput {
    AggInput {
        client,
        delta,
        n_samples: 100 + (client as u64 * 37) % 400,
        train_loss: 1.0 + client as f32 * 0.01,
        update_var: 0.01,
    }
}

fn human(bytes: u64) -> String {
    if bytes >= 1 << 30 {
        format!("{:.2} GiB", bytes as f64 / (1u64 << 30) as f64)
    } else {
        format!("{:.1} MiB", bytes as f64 / (1u64 << 20) as f64)
    }
}

fn main() {
    let budget = budget_from_env(3000);
    let strategy = strategy_from_config(&Aggregation::FedAvg);
    let mut stats: Vec<BenchStats> = Vec::new();
    let mut memo: Vec<String> = Vec::new();

    for (k, p) in [(20usize, 250_000usize), (60, 1_000_000)] {
        let mut rng = Rng::new(42);
        let global: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        // one template per client; `clone()` below plays the role of
        // decoding the arrived update into a dense delta
        let templates: Vec<Vec<f32>> = (0..k)
            .map(|c| template_delta(p, 1000 + c as u64))
            .collect();

        // sanity: streaming matches the old blocked kernel to f32
        // tolerance (op order differs, so bit-identity is not expected
        // here — it IS expected, and pinned by test, between streaming
        // and the batch wrapper)
        {
            let inputs: Vec<AggInput> = templates
                .iter()
                .enumerate()
                .map(|(c, t)| input(c as u32, t.clone()))
                .collect();
            let old = blocked_batch_aggregate(&global, &inputs);
            let mut agg = RoundAggregator::new(strategy.clone(), p);
            for i in &inputs {
                agg.fold(i).unwrap();
            }
            let streamed = agg.finalize(&global, &mut SgdServer).unwrap();
            for (a, b) in old.iter().zip(&streamed.new_params) {
                assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "paths diverged");
            }
        }

        stats.push(bench(&format!("buffered  k={k} P={}k", p / 1000), budget, || {
            // decode everything first (O(k·P) alive), then the old
            // block-major kernel
            let inputs: Vec<AggInput> = templates
                .iter()
                .enumerate()
                .map(|(c, t)| input(c as u32, t.clone()))
                .collect();
            let out = blocked_batch_aggregate(&global, &inputs);
            std::hint::black_box(out.len());
        }));
        stats.push(bench(&format!("streaming k={k} P={}k", p / 1000), budget, || {
            // decode-fold-free per arrival (one delta alive at a time)
            let mut agg = RoundAggregator::new(strategy.clone(), p);
            for (c, t) in templates.iter().enumerate() {
                let one = input(c as u32, t.clone());
                agg.fold(&one).unwrap();
            }
            let out = agg.finalize(&global, &mut SgdServer).unwrap();
            std::hint::black_box(out.new_params.len());
        }));

        let buffered_peak = (4 * p as u64) * k as u64 + 8 * p as u64;
        let streaming_peak = 4 * p as u64 + 8 * p as u64;
        memo.push(format!(
            "k={k} P={}k: collection bytes held — buffered {} vs streaming {} ({:.0}× less)",
            p / 1000,
            human(buffered_peak),
            human(streaming_peak),
            buffered_peak as f64 / streaming_peak as f64,
        ));
    }

    print_table(
        "collect+aggregate round cost (old blocked batch vs streaming fold)",
        &stats,
    );
    println!();
    for line in &memo {
        println!("{line}");
    }
    let (buf, st) = (&stats[2], &stats[3]);
    println!(
        "\n60 clients × 1M params: buffered {} vs streaming {} per round \
         (streaming trades accumulator bandwidth for O(P) memory + overlap with arrival)",
        fmt_ns(buf.mean_ns),
        fmt_ns(st.mean_ns),
    );
    let extra = json_num_obj(&[
        ("buffered_round_ns_60x1m", buf.mean_ns),
        ("streaming_round_ns_60x1m", st.mean_ns),
        ("buffered_peak_bytes_60x1m", (4.0 * 1e6) * 60.0 + 8.0 * 1e6),
        ("streaming_peak_bytes_60x1m", 4.0 * 1e6 + 8.0 * 1e6),
    ]);
    write_json_report(
        "BENCH_streaming.json",
        "hotpath_streaming",
        &stats,
        &[("collection", extra)],
    )
    .unwrap();
}
