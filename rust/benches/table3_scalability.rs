//! Table 3 reproduction as a bench target: virtual-time scalability of
//! total training time from 10 to 60 clients over a fixed global
//! workload, plus timing of the simulator itself.

use fedhpc::benchkit::{bench, print_table};
use fedhpc::config::presets::paper_testbed;
use fedhpc::experiments::{run_sim, SimTiming};
use std::time::Duration;

fn cfg_for(n: usize, rounds: usize) -> fedhpc::config::ExperimentConfig {
    let total_samples = 61_440;
    let mut cfg = paper_testbed();
    let gpu_cloud = n / 6 + usize::from(n % 6 > 3);
    let cpu_cloud = n / 4;
    let gpu_hpc = n / 3;
    let cpu_hpc = n - gpu_cloud - cpu_cloud - gpu_hpc;
    cfg.cluster.nodes = vec![
        ("p3.2xlarge".into(), gpu_cloud),
        ("t3.large".into(), cpu_cloud),
        ("hpc-rtx6000".into(), gpu_hpc),
        ("hpc-cpu".into(), cpu_hpc),
    ];
    cfg.selection.clients_per_round = (n * 2 / 3).max(1);
    cfg.data.samples_per_client = total_samples / n;
    cfg.train.rounds = rounds;
    cfg.straggler.partial_k = Some((cfg.selection.clients_per_round * 3 / 5).max(1));
    cfg
}

fn main() {
    // the table itself (100 virtual rounds, exactly E2)
    println!("=== Table 3 (virtual time, 100 rounds) ===");
    println!("{:>8} {:>14} {:>9}", "clients", "total time", "speedup");
    let mut base = None;
    for n in [10usize, 20, 30, 40, 50, 60] {
        // seed-averaged: the speed lottery makes single sims noisy
        let mut t = 0.0;
        for seed in [7u64, 8, 9] {
            let mut cfg = cfg_for(n, 100);
            cfg.seed = seed;
            t += run_sim(&cfg, &SimTiming::default(), false).unwrap().total_time_s / 3.0;
        }
        let b = *base.get_or_insert(t);
        println!("{:>8} {:>12.1} m {:>8.2}x", n, t / 60.0, b / t);
    }
    println!("(paper: 100/58/43/33/27/22 min → 1.00/1.72/2.32/3.03/3.70/4.55x)");

    // how fast is the simulator (so sweeps stay cheap)
    let mut stats = Vec::new();
    for n in [10usize, 60] {
        let cfg = cfg_for(n, 10);
        stats.push(bench(
            &format!("run_sim {n} clients x 10 rounds"),
            Duration::from_secs(2),
            || {
                std::hint::black_box(run_sim(&cfg, &SimTiming::default(), false).unwrap());
            },
        ));
    }
    print_table("simulator throughput", &stats);
}
