//! Transport layer benchmarks.
//!
//! Three tiers: message codec round-trips, single-connection transport
//! round-trips (inproc "MPI" vs framed-TCP "gRPC"), and a fleet-scale
//! sweep — thousands of concurrent registered sockets completing
//! broadcast→reply rounds against one readiness-driven server.
//!
//! Knobs:
//! * `FEDHPC_BENCH_SOCKETS` — fleet size target (default 10000). The
//!   bench opens both ends of every loopback connection in this
//!   process, so the achievable count is bounded by `ulimit -n`; the
//!   achieved count is reported, not assumed.
//! * `FEDHPC_BENCH_BUDGET_MS` — per-case time budget (CI smoke).
//!
//! Emits `BENCH_transport.json`: per-case timing stats plus fleet round
//! p50/p99 latency and broadcast bytes-on-wire compressed vs not.

use fedhpc::benchkit::{
    bench, budget_from_env, json_num_obj, print_table, write_json_report, BenchStats,
};
use fedhpc::compress::Encoded;
use fedhpc::config::{CompressionConfig, TransportConfig};
use fedhpc::network::framing;
use fedhpc::network::inproc::InprocHub;
use fedhpc::network::tcp::{TcpClient, TcpServer};
use fedhpc::network::{
    pre_encode_dense, ClientProfile, ClientTransport, LinkShaper, Msg, ServerTransport,
    TrafficLog, UpdateStats,
};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn profile() -> ClientProfile {
    ClientProfile {
        speed_factor: 1.0,
        mem_gb: 1.0,
        link_bw: 1e9,
        n_samples: 1,
        bench_step_ms: 1.0,
    }
}

fn update_msg(p: usize) -> Msg {
    Msg::Update {
        round: 1,
        client: 0,
        base_version: 1,
        delta: Encoded::Dense(vec![0.5f32; p]),
        stats: UpdateStats {
            n_samples: 100,
            train_loss: 1.0,
            steps: 10,
            compute_ms: 5.0,
            update_var: 0.01,
        },
    }
}

fn round_end(round: u32) -> Msg {
    Msg::RoundEnd {
        round,
        model_version: round,
    }
}

/// Model broadcast with mildly structured (compressible, not constant)
/// parameters — the shape frame compression sees in practice.
fn broadcast_msg(p: usize) -> Msg {
    let params: Vec<f32> = (0..p).map(|i| (i % 97) as f32 / 97.0).collect();
    Msg::RoundStart {
        round: 1,
        model_version: 1,
        deadline_ms: 1_000,
        lr: 0.1,
        mu: 0.0,
        local_epochs: 1,
        params: Encoded::PreEncoded(pre_encode_dense(&params)),
        mask_seed: 0,
        compression: CompressionConfig::NONE,
    }
}

fn percentile(sorted_ns: &[f64], p: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ns.len() as f64 * p) as usize).min(sorted_ns.len() - 1);
    sorted_ns[idx]
}

/// One fleet driver: connect + register a contiguous id range, report
/// how many sockets came up, then serve rounds — read each broadcast,
/// answer with a heartbeat — until Shutdown or disconnect.
fn fleet_driver(addr: String, ids: std::ops::Range<u32>, up_tx: mpsc::Sender<usize>) {
    let mut socks: Vec<(u32, TcpStream)> = Vec::with_capacity(ids.len());
    for id in ids {
        let mut attempt = 0;
        let sock = loop {
            match TcpStream::connect(&addr) {
                Ok(s) => break Some(s),
                Err(_) if attempt < 3 => {
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => break None, // fd limit or backlog: report what we got
            }
        };
        let mut sock = match sock {
            Some(s) => s,
            None => break,
        };
        sock.set_nodelay(true).ok();
        let reg = Msg::Register {
            client: id,
            profile: profile(),
        };
        let frame = framing::build_frame(&reg.encode(), None, false).unwrap();
        if framing::write_frame(&mut sock, &frame).is_err() {
            break;
        }
        socks.push((id, sock));
    }
    let _ = up_tx.send(socks.len());
    drop(up_tx);
    if socks.is_empty() {
        return;
    }
    loop {
        for (id, sock) in &mut socks {
            let (payload, _) = match framing::read_frame(sock) {
                Ok(x) => x,
                Err(_) => return,
            };
            match Msg::decode(&payload) {
                Ok(Msg::Shutdown) | Err(_) => return,
                Ok(msg) => {
                    let hb = Msg::Heartbeat {
                        client: *id,
                        round: match msg {
                            Msg::RoundEnd { round, .. } => round,
                            _ => 0,
                        },
                    };
                    let frame = framing::build_frame(&hb.encode(), None, false).unwrap();
                    if framing::write_frame(sock, &frame).is_err() {
                        return;
                    }
                }
            }
        }
    }
}

/// Fleet-scale sweep: `target` concurrent sockets, broadcast→reply
/// rounds. Returns (stats row, achieved sockets, sorted round samples).
fn fleet_rounds(target: usize, budget: Duration) -> (BenchStats, usize, Vec<f64>) {
    let cfg = TransportConfig {
        max_connections: target + 64,
        compression: false, // tiny control frames; measure the reactor
        ..TransportConfig::default()
    };
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind_with("127.0.0.1:0", &cfg, traffic).unwrap();
    let addr = server.local_addr.to_string();

    let drivers = 8usize.min(target.max(1));
    let chunk = target.div_ceil(drivers);
    let (up_tx, up_rx) = mpsc::channel::<usize>();
    let mut handles = Vec::new();
    for d in 0..drivers {
        let lo = (d * chunk).min(target) as u32;
        let hi = ((d + 1) * chunk).min(target) as u32;
        let tx = up_tx.clone();
        let a = addr.clone();
        handles.push(std::thread::spawn(move || fleet_driver(a, lo..hi, tx)));
    }
    drop(up_tx);
    let achieved: usize = up_rx.iter().sum();

    // drain the Registers, learning which ids actually made it up
    let mut ids = Vec::with_capacity(achieved);
    while ids.len() < achieved {
        match server.recv_timeout(Duration::from_secs(10)) {
            Ok(Some((from, Msg::Register { .. }))) => ids.push(from),
            Ok(Some(_)) => {}
            _ => break,
        }
    }

    // rounds: broadcast a RoundEnd to every peer, collect every reply
    let mut samples_ns: Vec<f64> = Vec::new();
    let deadline = Instant::now() + budget;
    let mut round = 0u32;
    while round < 3 || (Instant::now() < deadline && round < 200) {
        round += 1;
        let t0 = Instant::now();
        let mut expected = 0usize;
        ids.retain(|&id| {
            let ok = server.send_to(id, &round_end(round)).is_ok();
            expected += ok as usize;
            ok
        });
        let mut got = 0usize;
        while got < expected {
            match server.recv_timeout(Duration::from_secs(10)) {
                Ok(Some(_)) => got += 1,
                _ => break,
            }
        }
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if ids.is_empty() {
            break;
        }
    }

    for &id in &ids {
        let _ = server.send_to(id, &Msg::Shutdown);
    }
    drop(server); // EOFs any driver still mid-read
    for h in handles {
        let _ = h.join();
    }

    samples_ns.sort_by(f64::total_cmp);
    let n = samples_ns.len().max(1);
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let stats = BenchStats {
        name: format!("fleet round ({achieved} sockets)"),
        iters: samples_ns.len(),
        mean_ns: mean,
        median_ns: percentile(&samples_ns, 0.5),
        p95_ns: percentile(&samples_ns, 0.95),
        min_ns: samples_ns.first().copied().unwrap_or(0.0),
    };
    (stats, achieved, samples_ns)
}

fn main() {
    let budget = budget_from_env(2_000);
    let mut stats = Vec::new();

    // codec
    let msg_small = update_msg(1_000);
    let msg_big = update_msg(250_000);
    let enc_big = msg_big.encode();
    stats.push(bench("Msg::encode 250k-param update", budget, || {
        std::hint::black_box(msg_big.encode().len());
    }));
    stats.push(bench("Msg::decode 250k-param update", budget, || {
        std::hint::black_box(Msg::decode(&enc_big).unwrap());
    }));

    // frame compression: bytes on the wire for a model broadcast
    let bcast = broadcast_msg(250_000);
    let (head, shared) = bcast.encode_split();
    let wire_plain = framing::frame_uncompressed(&head, shared.as_ref())
        .unwrap()
        .wire_len();
    let wire_lz = framing::build_frame(&head, shared.as_ref(), true)
        .unwrap()
        .wire_len();
    stats.push(bench("frame+compress 250k-param broadcast", budget, || {
        std::hint::black_box(
            framing::build_frame(&head, shared.as_ref(), true)
                .unwrap()
                .wire_len(),
        );
    }));

    // inproc (MPI-like) round trip
    let traffic = Arc::new(TrafficLog::new());
    let hub = InprocHub::new(traffic.clone());
    let client = hub.add_client(0, LinkShaper::unshaped());
    let server = hub.server();
    stats.push(bench("inproc roundtrip 1k-param", budget, || {
        client.send(&msg_small).unwrap();
        server.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
    }));
    stats.push(bench("inproc roundtrip 250k-param", budget, || {
        client.send(&msg_big).unwrap();
        server.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
    }));

    // tcp (gRPC-like) round trip over the reactor
    let tcp_server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
    let addr = tcp_server.local_addr.to_string();
    let tcp_client = TcpClient::connect(
        &addr,
        &Msg::Register {
            client: 0,
            profile: profile(),
        },
        LinkShaper::unshaped(),
        traffic,
    )
    .unwrap();
    tcp_server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
    stats.push(bench("tcp roundtrip 1k-param", budget, || {
        tcp_client.send(&msg_small).unwrap();
        tcp_server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
    }));
    stats.push(bench("tcp roundtrip 250k-param", budget, || {
        tcp_client.send(&msg_big).unwrap();
        tcp_server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
    }));
    drop(tcp_client);
    drop(tcp_server);

    // fleet sweep
    let target: usize = std::env::var("FEDHPC_BENCH_SOCKETS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);
    let (fleet, achieved, samples_ns) = fleet_rounds(target, budget);
    let p50_ms = percentile(&samples_ns, 0.5) / 1e6;
    let p99_ms = percentile(&samples_ns, 0.99) / 1e6;
    stats.push(fleet);

    print_table("transport layer (inproc='MPI' vs tcp='gRPC')", &stats);
    println!(
        "\nfleet: {achieved}/{target} sockets, round p50 {p50_ms:.2} ms, p99 {p99_ms:.2} ms"
    );
    let ratio = wire_plain as f64 / wire_lz.max(1) as f64;
    println!(
        "broadcast wire bytes: {wire_plain} plain vs {wire_lz} compressed ({ratio:.2}x)"
    );

    let extra = json_num_obj(&[
        ("sockets_target", target as f64),
        ("sockets_achieved", achieved as f64),
        ("fleet_round_p50_ms", p50_ms),
        ("fleet_round_p99_ms", p99_ms),
        ("bcast_wire_bytes_uncompressed", wire_plain as f64),
        ("bcast_wire_bytes_compressed", wire_lz as f64),
        ("bcast_compression_ratio", ratio),
    ]);
    write_json_report("BENCH_transport.json", "transport", &stats, &[("metrics", extra)])
        .expect("writing BENCH_transport.json");
}
