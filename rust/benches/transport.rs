//! Transport layer benchmarks: message codec round-trip, inproc
//! hub round-trip, and framed-TCP round-trip with model-sized payloads
//! (the "gRPC vs MPI" comparison from the paper's communication layer).

use fedhpc::benchkit::{bench, print_table};
use fedhpc::compress::Encoded;
use fedhpc::network::inproc::InprocHub;
use fedhpc::network::tcp::{TcpClient, TcpServer};
use fedhpc::network::{
    ClientProfile, ClientTransport, LinkShaper, Msg, ServerTransport, TrafficLog, UpdateStats,
};
use std::sync::Arc;
use std::time::Duration;

fn update_msg(p: usize) -> Msg {
    Msg::Update {
        round: 1,
        client: 0,
        base_version: 1,
        delta: Encoded::Dense(vec![0.5f32; p]),
        stats: UpdateStats {
            n_samples: 100,
            train_loss: 1.0,
            steps: 10,
            compute_ms: 5.0,
            update_var: 0.01,
        },
    }
}

fn main() {
    let budget = Duration::from_secs(2);
    let mut stats = Vec::new();

    // codec
    let msg_small = update_msg(1_000);
    let msg_big = update_msg(250_000);
    let enc_big = msg_big.encode();
    stats.push(bench("Msg::encode 250k-param update", budget, || {
        std::hint::black_box(msg_big.encode().len());
    }));
    stats.push(bench("Msg::decode 250k-param update", budget, || {
        std::hint::black_box(Msg::decode(&enc_big).unwrap());
    }));

    // inproc (MPI-like) round trip
    let traffic = Arc::new(TrafficLog::new());
    let hub = InprocHub::new(traffic.clone());
    let client = hub.add_client(0, LinkShaper::unshaped());
    let server = hub.server();
    stats.push(bench("inproc roundtrip 1k-param", budget, || {
        client.send(&msg_small).unwrap();
        server.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
    }));
    stats.push(bench("inproc roundtrip 250k-param", budget, || {
        client.send(&msg_big).unwrap();
        server.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
    }));

    // tcp (gRPC-like) round trip
    let tcp_server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
    let addr = tcp_server.local_addr.to_string();
    let tcp_client = TcpClient::connect(
        &addr,
        &Msg::Register {
            client: 0,
            profile: ClientProfile {
                speed_factor: 1.0,
                mem_gb: 1.0,
                link_bw: 1e9,
                n_samples: 1,
                bench_step_ms: 1.0,
            },
        },
        LinkShaper::unshaped(),
        traffic,
    )
    .unwrap();
    tcp_server.recv_timeout(Duration::from_secs(2)).unwrap(); // drain Register
    stats.push(bench("tcp roundtrip 1k-param", budget, || {
        tcp_client.send(&msg_small).unwrap();
        tcp_server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
    }));
    stats.push(bench("tcp roundtrip 250k-param", budget, || {
        tcp_client.send(&msg_big).unwrap();
        tcp_server
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
    }));

    print_table("transport layer (inproc='MPI' vs tcp='gRPC')", &stats);
}
