//! Deterministic end-to-end sim regressions under injected faults
//! (ISSUE 4 satellite + acceptance demo).
//!
//! Virtual-time scenarios with stragglers/dropouts right at the
//! deadline boundary, pinned in **both** round engines:
//!
//! * sync — exact partial-k cutoff, per-round reporter sets, and the
//!   final model hash are identical for a fixed seed across runs;
//! * async (`async_fedbuff`) — the same seed reproduces the identical
//!   commit sequence (per-commit reporter sets + staleness) and final
//!   model hash twice, and a 4×-straggler scenario reaches the
//!   sync-mode eval accuracy in ≤ 60% of the sync virtual wall-clock
//!   time (the paper's fault-tolerance claim, made measurable).
//!
//! These tests deliberately avoid hard-coded magic values: the pin is
//! run-twice bit-equality (any nondeterminism in selection, fault
//! draws, event ordering or aggregation breaks it) plus structural
//! assertions the engines must satisfy for any seed.

use fedhpc::config::{Partition, RoundMode, StalenessFn};
use fedhpc::config::presets::quickstart;
use fedhpc::experiments::{run_sim, SimTiming};

/// Homogeneous mock-training base: injected faults are the only
/// heterogeneity, which keeps the deadline/staleness math legible.
fn fault_cfg(name: &str) -> fedhpc::config::ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.into();
    cfg.mock_runtime = true;
    cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 6)];
    cfg.selection.clients_per_round = 4;
    cfg.train.rounds = 6;
    cfg.train.lr = 0.2;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.data.partition = Partition::Iid;
    cfg.faults.straggler_prob = 0.5;
    cfg.faults.straggler_factor = 4.0;
    cfg.faults.dropout_prob = 0.2;
    cfg
}

#[test]
fn sync_sim_with_faults_replays_bit_identically() {
    let mut cfg = fault_cfg("sim_faults_sync");
    cfg.faults.straggler_prob = 0.4;
    cfg.train.rounds = 10;
    // deadline at the straggler boundary: a normal client finishes in
    // ~0.07 virtual seconds, a 4× straggler in ~0.27 — the 150 ms
    // deadline admits the former and cuts the latter
    cfg.straggler.deadline_ms = Some(150);
    cfg.straggler.partial_k = Some(2);
    let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();

    // determinism: identical reporter sets, times and final model
    assert_eq!(a.details, b.details);
    assert_eq!(a.model_hash, b.model_hash);
    assert!(a.model_hash.is_some());
    assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());

    // structure: the partial-k cutoff is exact, fault accounting adds up
    assert_eq!(a.report.rounds.len(), 10);
    let mut saw_full_cutoff = false;
    let mut misses = 0u32;
    for (r, d) in a.report.rounds.iter().zip(&a.details) {
        assert!(r.reported <= 2, "round {} exceeded partial_k", r.round);
        assert_eq!(r.reported as usize, d.reporters.len());
        assert_eq!(r.dropped, r.selected - r.reported);
        assert!(d.reporters.iter().all(|&(_, s)| s == 0), "sync is stale-free");
        saw_full_cutoff |= r.reported == 2;
        misses += r.deadline_misses;
    }
    assert!(saw_full_cutoff, "no round hit the partial-k cutoff");
    assert!(
        misses > 0,
        "4x stragglers under a 150 ms deadline must miss sometimes"
    );

    // a different seed produces a different trajectory
    cfg.seed += 1;
    let c = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    assert_ne!(a.details, c.details);
}

#[test]
fn async_sim_with_faults_replays_bit_identically() {
    let mut cfg = fault_cfg("sim_faults_async");
    cfg.train.rounds = 10; // commits
    cfg.round_mode = RoundMode::BufferedAsync {
        buffer_k: 3,
        max_staleness: 50,
        staleness: StalenessFn::Polynomial { alpha: 0.5 },
    };
    let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();

    // the acceptance pin: identical commit sequence + final model hash
    assert_eq!(a.details, b.details);
    assert_eq!(a.model_hash, b.model_hash);
    assert!(a.model_hash.is_some());

    // structure: every commit closes on exactly buffer_k folds, and
    // the 4× stragglers surface as *stale* folds, not drops
    assert_eq!(a.report.rounds.len(), 10);
    for (r, d) in a.report.rounds.iter().zip(&a.details) {
        assert_eq!(r.reported, 3);
        assert_eq!(d.reporters.len(), 3);
    }
    let max_stale = a
        .details
        .iter()
        .flat_map(|d| d.reporters.iter().map(|&(_, s)| s))
        .max()
        .unwrap();
    assert!(max_stale > 0, "stragglers should fold stale, not vanish");

    cfg.seed += 1;
    let c = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    assert_ne!(a.details, c.details);
}

/// ISSUE 8 acceptance pin: the parallel sharded ingest changes *when*
/// folds execute, never *what* they compute. A full faulty run with
/// `ingest_threads = 1` (serial reference, no pool) and the same run
/// with a multi-worker shard pool must produce the identical replay —
/// per-round reporter sets, virtual times and the final model hash —
/// in both round engines, run twice each to also pin run-to-run
/// determinism of the pool itself.
#[test]
fn sharded_ingest_replays_serial_run_bit_identically_in_both_engines() {
    let engines: [(&str, Option<RoundMode>); 2] = [
        ("sync", None),
        (
            "async",
            Some(RoundMode::BufferedAsync {
                buffer_k: 3,
                max_staleness: 50,
                staleness: StalenessFn::Polynomial { alpha: 0.5 },
            }),
        ),
    ];
    for (engine, mode) in engines {
        let mut cfg = fault_cfg("sim_sharded_ingest");
        cfg.straggler.deadline_ms = Some(150);
        cfg.straggler.partial_k = Some(2);
        if let Some(m) = mode {
            cfg.straggler.partial_k = None;
            cfg.round_mode = m;
        }

        cfg.ingest_threads = 1; // serial reference path
        let serial = run_sim(&cfg, &SimTiming::default(), true).unwrap();

        for threads in [2u32, 4, 0 /* auto */] {
            cfg.ingest_threads = threads;
            let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
            let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();
            assert_eq!(
                serial.details, a.details,
                "{engine}: replay diverged at ingest_threads={threads}"
            );
            assert_eq!(
                serial.model_hash, a.model_hash,
                "{engine}: model diverged at ingest_threads={threads}"
            );
            assert!(serial.model_hash.is_some());
            assert_eq!(
                serial.total_time_s.to_bits(),
                a.total_time_s.to_bits(),
                "{engine}: virtual time diverged at ingest_threads={threads}"
            );
            // run-to-run: the pool schedules freely, folds don't move
            assert_eq!(a.details, b.details, "{engine}: run-twice at {threads}");
            assert_eq!(a.model_hash, b.model_hash, "{engine}: run-twice at {threads}");
        }
    }
}

/// Acceptance demo: under 4× stragglers, buffered-async reaches the
/// synchronous engine's final eval accuracy in ≤ 60% of the virtual
/// wall-clock time the synchronous engine needed to get there.
#[test]
fn async_mode_reaches_sync_accuracy_in_much_less_virtual_time() {
    let base = {
        let mut cfg = fault_cfg("async_vs_sync");
        cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 12)];
        cfg.selection.clients_per_round = 8;
        cfg.faults.dropout_prob = 0.0; // isolate the straggler effect
        cfg
    };

    // sync baseline: no mitigation (waits for every straggler)
    let mut sync_cfg = base.clone();
    sync_cfg.straggler.deadline_ms = None;
    sync_cfg.straggler.partial_k = None;
    sync_cfg.train.rounds = 6;
    let sync = run_sim(&sync_cfg, &SimTiming::default(), true).unwrap();
    let target = sync.report.final_accuracy().unwrap();
    let t_sync = sync
        .time_to_accuracy(target)
        .expect("sync run must reach its own final accuracy");

    // async: same fleet, same faults, FedBuff commits of 4
    let mut async_cfg = base;
    async_cfg.round_mode = RoundMode::BufferedAsync {
        buffer_k: 4,
        max_staleness: 50,
        staleness: StalenessFn::Polynomial { alpha: 0.5 },
    };
    async_cfg.train.rounds = 100; // commit budget; stops at the target
    async_cfg.train.target_accuracy = Some(target);
    let asynced = run_sim(&async_cfg, &SimTiming::default(), true).unwrap();
    let t_async = asynced
        .time_to_accuracy(target)
        .expect("async run never reached the sync accuracy");
    assert!(
        t_async <= 0.6 * t_sync,
        "async {t_async:.2}s vs sync {t_sync:.2}s — expected ≤ 60%"
    );
}
