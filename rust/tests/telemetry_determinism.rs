//! PR 7 acceptance: a live scraper must be pure observation. Running
//! the same seeded virtual-time sim with and without a concurrent
//! `/metrics` + `/status` poller has to produce bit-identical results —
//! same final model hash, same replay log, same CSV rows.
//!
//! The instrumentation sites write to the global registry in both runs;
//! what this test pins is that *reading* it (render + status under
//! load) never feeds back into the training path.

use fedhpc::config::{presets::quickstart, ExperimentConfig, RoundMode, StalenessFn};
use fedhpc::experiments::{run_sim, SimReport, SimTiming};
use fedhpc::telemetry::{global, ControlPlane, TelemetryServer};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn small_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.to_string();
    cfg.mock_runtime = true;
    cfg.train.rounds = 5;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg
}

/// The replay-relevant projection of a [`SimReport`]: everything the
/// deterministic-regression suite pins, plus the serialized CSV rows.
fn fingerprint(sim: &SimReport) -> (Option<u64>, Vec<String>, String) {
    let csv: String = sim.report.rounds.iter().map(|r| r.to_csv_row() + "\n").collect();
    let details: Vec<String> = sim.details.iter().map(|d| format!("{d:?}")).collect();
    (sim.model_hash, details, csv)
}

fn scrape(addr: &str, path: &str) -> String {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return String::new(),
    };
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let req = format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n");
    if stream.write_all(req.as_bytes()).is_err() {
        return String::new();
    }
    let mut text = String::new();
    let _ = stream.read_to_string(&mut text);
    text
}

/// Run `cfg` while a scraper thread hammers the live endpoint backed
/// by the GLOBAL registry (the one the sim's instrumentation writes
/// to). Returns the sim result and the number of successful scrapes.
fn run_with_scraper(cfg: &ExperimentConfig) -> (SimReport, u64) {
    let cp = Arc::new(ControlPlane::new());
    cp.set_status("state=sim".to_string());
    cp.mark_ready();
    let srv = TelemetryServer::bind("127.0.0.1:0", global().clone(), cp).unwrap();
    let addr = srv.local_addr().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let (addr, stop) = (addr.clone(), stop.clone());
        std::thread::spawn(move || {
            let mut ok = 0u64;
            while !stop.load(Ordering::Acquire) {
                if scrape(&addr, "/metrics").contains("HTTP/1.1 200") {
                    ok += 1;
                }
                let _ = scrape(&addr, "/status");
                std::thread::sleep(Duration::from_millis(2));
            }
            ok
        })
    };
    // one scrape is guaranteed before the run even starts, so the
    // "concurrent observer" claim can't vacuously pass on a fast sim
    let warmup = scrape(&addr, "/metrics");
    assert!(warmup.contains("HTTP/1.1 200"), "warmup scrape failed: {warmup:?}");
    let sim = run_sim(cfg, &SimTiming::default(), true).unwrap();
    stop.store(true, Ordering::Release);
    let ok = scraper.join().unwrap();
    srv.shutdown();
    (sim, ok + 1)
}

#[test]
fn sync_sim_is_bit_identical_under_live_scraping() {
    let cfg = small_cfg("det_sync");
    let quiet = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let (scraped, ok) = run_with_scraper(&cfg);
    assert!(ok >= 1, "the scraper never completed a request");
    assert!(quiet.model_hash.is_some(), "with_training sims carry a hash");
    assert_eq!(fingerprint(&quiet), fingerprint(&scraped));
    assert_eq!(quiet.total_time_s, scraped.total_time_s);
}

#[test]
fn async_sim_is_bit_identical_under_live_scraping() {
    let mut cfg = small_cfg("det_async");
    cfg.round_mode = RoundMode::BufferedAsync {
        buffer_k: 3,
        max_staleness: 20,
        staleness: StalenessFn::Polynomial { alpha: 0.5 },
    };
    let quiet = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let (scraped, ok) = run_with_scraper(&cfg);
    assert!(ok >= 1, "the scraper never completed a request");
    assert_eq!(fingerprint(&quiet), fingerprint(&scraped));
}
