//! Property-based tests over coordinator invariants (testkit is the
//! in-tree proptest replacement — see rust/src/testkit).
//!
//! Each property runs hundreds of generated cases including
//! pathological values (zeros, ±1e30, ties); failures print a replay
//! seed (FEDHPC_PROP_SEED).

use fedhpc::compress::{
    compress, decompress, dropout_mask_indices, quantize, sparsify_topk, QuantBits,
};
use fedhpc::config::{Aggregation, CompressionConfig, WeightScheme};
use fedhpc::network::{ClientProfile, Msg, UpdateStats};
use fedhpc::orchestrator::planner::planner_by_name;
use fedhpc::orchestrator::{aggregate, AggInput, ClientRegistry, DispatchPlan, PlanContext};
use fedhpc::testkit::{check, Gen};

fn any_compression(g: &mut Gen) -> CompressionConfig {
    CompressionConfig {
        quant_bits: *g.pick(&[8u8, 16, 32]),
        topk_frac: *g.pick(&[0.05f32, 0.25, 0.5, 1.0]),
        dropout_keep: *g.pick(&[0.3f32, 0.7, 1.0]),
    }
}

#[test]
fn prop_codec_roundtrip_preserves_survivors_and_zeroes_rest() {
    check("codec roundtrip", 300, |g| {
        let v = g.f32_vec_nasty(2000);
        // huge magnitudes destroy int8 resolution for everything else —
        // that's expected; bound inputs to a sane gradient range
        let v: Vec<f32> = v
            .iter()
            .map(|&x| if x.abs() > 1e3 { x.signum() * 1e3 } else { x })
            .collect();
        let cfg = any_compression(g);
        let seed = g.rng.next_u64();
        let enc = compress(&v, &cfg, seed);
        let back = decompress(&enc, v.len()).unwrap();
        assert_eq!(back.len(), v.len());
        // quantization error bound: scale/2 on surviving coords
        let maxabs = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let tol = match cfg.quant_bits {
            8 => maxabs / 127.0,
            16 => maxabs / 32767.0,
            _ => 1e-6,
        };
        for (a, b) in v.iter().zip(&back) {
            if *b != 0.0 {
                assert!(
                    (a - b).abs() <= tol + 1e-6,
                    "survivor error {} > {tol}",
                    (a - b).abs()
                );
            }
        }
        // wire bytes never exceed dense bytes (+tiny header)
        assert!(enc.wire_bytes() <= 4 * v.len() as u64 + 16);
    });
}

#[test]
fn prop_quantize_error_bound_and_determinism() {
    check("quantize", 300, |g| {
        let v = g.f32_vec(4096);
        for bits in [QuantBits::B8, QuantBits::B16] {
            let q1 = quantize(&v, bits);
            let q2 = quantize(&v, bits);
            assert_eq!(q1, q2, "quantize must be deterministic");
            let back: Vec<f32> = fedhpc::compress::dequantize(&q1);
            for (a, b) in v.iter().zip(&back) {
                // scale/2 quantization error + f32 rounding of the
                // divide/round/multiply round-trip itself
                let tol = q1.scale / 2.0 + a.abs() * 1e-5 + 1e-7;
                assert!((a - b).abs() <= tol, "err {} > {tol}", (a - b).abs());
            }
        }
    });
}

#[test]
fn prop_sparsify_keeps_at_least_k_and_all_larger() {
    check("sparsify", 300, |g| {
        let v = g.f32_vec_nasty(3000);
        let k = g.usize_in(1, v.len());
        let s = sparsify_topk(&v, k);
        assert!(s.idx.len() >= k.min(v.len()), "kept {} < k {k}", s.idx.len());
        // no kept value is smaller in magnitude than any dropped value
        let kept: std::collections::HashSet<u32> = s.idx.iter().copied().collect();
        let min_kept = s
            .val
            .iter()
            .map(|x| x.abs())
            .fold(f32::INFINITY, f32::min);
        for (i, &x) in v.iter().enumerate() {
            if !kept.contains(&(i as u32)) {
                assert!(
                    x.abs() <= min_kept,
                    "dropped |{}| > min kept {min_kept}",
                    x.abs()
                );
            }
        }
    });
}

#[test]
fn prop_dropout_mask_deterministic_sorted_bounded() {
    check("dropout mask", 300, |g| {
        let n = g.usize_in(1, 5000);
        let keep = g.f32_in(0.05, 1.0);
        let seed = g.rng.next_u64();
        let m1 = dropout_mask_indices(n, keep, seed);
        let m2 = dropout_mask_indices(n, keep, seed);
        assert_eq!(m1, m2);
        assert!(m1.windows(2).all(|w| w[0] < w[1]));
        assert!(m1.iter().all(|&i| (i as usize) < n));
        let expect = ((n as f64 * keep as f64).round() as usize).clamp(1, n);
        assert_eq!(m1.len(), expect);
    });
}

#[test]
fn prop_aggregation_weights_normalize_and_bound_result() {
    check("aggregation", 300, |g| {
        let p = g.usize_in(1, 200);
        let k = g.usize_in(1, 12);
        let global: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let inputs: Vec<AggInput> = (0..k)
            .map(|c| AggInput {
                client: c as u32,
                delta: (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect(),
                n_samples: g.usize_in(1, 1000) as u64,
                train_loss: g.f32_in(0.0, 10.0),
                update_var: g.f32_in(0.0, 5.0),
            })
            .collect();
        let strat = *g.pick(&[
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
            // buffered order statistics: results stay within the
            // per-coordinate value range, so the same bound applies
            Aggregation::TrimmedMean { trim_frac: 0.25 },
            Aggregation::CoordinateMedian,
        ]);
        let out = aggregate(&global, &inputs, strat).unwrap();
        let wsum: f64 = out.weights.iter().map(|(_, w)| w).sum();
        assert!((wsum - 1.0).abs() < 1e-9, "weights sum {wsum}");
        assert!(out.weights.iter().all(|(_, w)| *w >= 0.0));
        // convexity: new param within global ± max|delta|
        for j in 0..p {
            let max_d = inputs
                .iter()
                .map(|i| i.delta[j].abs())
                .fold(0f32, f32::max);
            let moved = (out.new_params[j] - global[j]).abs();
            assert!(
                moved <= max_d + 1e-5,
                "param {j} moved {moved} > max delta {max_d}"
            );
        }
    });
}

/// ISSUE satellite: the fused decode→fold ingest (`DecodedView` →
/// `fold_view`) must match densify-then-fold (`decompress` → `fold`)
/// **bit-for-bit** — for Dense, QDense, Sparse, QSparse and Masked
/// encodings (plus their pre-encoded wire-byte forms), every strategy
/// mode (streaming and buffered), random arrival-order permutations,
/// and injected signed zeros.
#[test]
fn prop_fused_fold_matches_densify_then_fold_bitwise() {
    use fedhpc::compress::{DecodedView, Encoded};
    use fedhpc::network::pre_encode;
    use fedhpc::orchestrator::strategy::registry::strategy_from_config;
    use fedhpc::orchestrator::strategy::SgdServer;
    use fedhpc::orchestrator::{RoundAggregator, ViewInput};
    check("fused ingest", 150, |g| {
        let p = g.usize_in(1, 1500);
        let k = g.usize_in(1, 6);
        let global: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let cfg = any_compression(g);
        let strat = *g.pick(&[
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
            Aggregation::TrimmedMean { trim_frac: 0.25 },
            Aggregation::CoordinateMedian,
        ]);
        struct Update {
            enc: Encoded,
            n_samples: u64,
            train_loss: f32,
            update_var: f32,
        }
        let updates: Vec<Update> = (0..k)
            .map(|c| {
                let mut v: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
                // signed-zero edge: stored and unstored zeros of both
                // signs must not make the paths diverge
                for _ in 0..g.usize_in(0, 4) {
                    let i = g.usize_in(0, p - 1);
                    v[i] = if g.bool() { 0.0 } else { -0.0 };
                }
                let enc = compress(&v, &cfg, g.rng.next_u64() ^ c as u64);
                let enc = if g.bool() {
                    // wire-byte form: the borrowed PreEncoded decode
                    Encoded::PreEncoded(pre_encode(&enc))
                } else {
                    enc
                };
                Update {
                    enc,
                    n_samples: g.usize_in(1, 1000) as u64,
                    train_loss: g.f32_in(0.0, 10.0),
                    update_var: g.f32_in(0.0, 5.0),
                }
            })
            .collect();
        // random arrival order, replayed identically through both paths
        let mut order: Vec<usize> = (0..k).collect();
        for i in (1..k).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        let strategy = strategy_from_config(&strat);
        let mut dense_agg = RoundAggregator::new(strategy.clone(), p);
        let mut view_agg = RoundAggregator::new(strategy, p);
        for &c in &order {
            let u = &updates[c];
            let dense = decompress(&u.enc, p).unwrap();
            dense_agg
                .fold(&AggInput {
                    client: c as u32,
                    delta: dense,
                    n_samples: u.n_samples,
                    train_loss: u.train_loss,
                    update_var: u.update_var,
                })
                .unwrap();
            let view = DecodedView::of(&u.enc, p).unwrap();
            view_agg
                .fold_view(&ViewInput {
                    client: c as u32,
                    view: &view,
                    n_samples: u.n_samples,
                    train_loss: u.train_loss,
                    update_var: u.update_var,
                })
                .unwrap();
        }
        let a = dense_agg.finalize(&global, &mut SgdServer).unwrap();
        let b = view_agg.finalize(&global, &mut SgdServer).unwrap();
        for (j, (x, y)) in a.new_params.iter().zip(&b.new_params).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{strat:?}/{cfg:?} diverged at coord {j}"
            );
        }
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.mean_train_loss.to_bits(), b.mean_train_loss.to_bits());
    });
}

/// ISSUE 8 satellite: the parallel sharded ingest (`ShardPool` →
/// `fold_shared`) must match the serial streaming fold (`fold_view`)
/// **bit-for-bit** — for Dense, QDense, Sparse, QSparse and Masked
/// encodings plus their pre-encoded wire-byte forms, every streaming
/// strategy, random arrival-order permutations (both paths replay the
/// same order), injected signed zeros, and shard counts
/// {1, 2, 3, 7, hardware} with varying worker counts. One addition
/// per element per update, in arrival order, at any partitioning.
#[test]
fn prop_sharded_ingest_matches_serial_bitwise_at_every_shard_count() {
    use fedhpc::compress::{DecodedView, Encoded, SharedDecoded};
    use fedhpc::network::pre_encode;
    use fedhpc::orchestrator::strategy::registry::strategy_from_config;
    use fedhpc::orchestrator::strategy::SgdServer;
    use fedhpc::orchestrator::{RoundAggregator, SharedInput, ViewInput};
    use fedhpc::util::parallel::{n_threads, ShardPool};
    use fedhpc::util::scratch::ScratchPool;
    use std::sync::Arc;
    check("sharded ingest", 60, |g| {
        let p = g.usize_in(1, 1500);
        let k = g.usize_in(1, 6);
        let global: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let cfg = any_compression(g);
        // sharded mode is the streaming strategies' opt-in (order
        // statistics buffer whole rounds and stay serial)
        let strat = *g.pick(&[
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ]);
        struct Update {
            enc: Arc<Encoded>,
            n_samples: u64,
            train_loss: f32,
            update_var: f32,
        }
        let updates: Vec<Update> = (0..k)
            .map(|c| {
                let mut v: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
                for _ in 0..g.usize_in(0, 4) {
                    let i = g.usize_in(0, p - 1);
                    v[i] = if g.bool() { 0.0 } else { -0.0 };
                }
                let enc = compress(&v, &cfg, g.rng.next_u64() ^ c as u64);
                let enc = if g.bool() {
                    Encoded::PreEncoded(pre_encode(&enc))
                } else {
                    enc
                };
                Update {
                    enc: Arc::new(enc),
                    n_samples: g.usize_in(1, 1000) as u64,
                    train_loss: g.f32_in(0.0, 10.0),
                    update_var: g.f32_in(0.0, 5.0),
                }
            })
            .collect();
        // one random arrival order, replayed through every path
        let mut order: Vec<usize> = (0..k).collect();
        for i in (1..k).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        let strategy = strategy_from_config(&strat);
        // serial reference: the PR 3 fused view fold
        let mut serial = RoundAggregator::new(strategy.clone(), p);
        for &c in &order {
            let u = &updates[c];
            let view = DecodedView::of(&u.enc, p).unwrap();
            serial
                .fold_view(&ViewInput {
                    client: c as u32,
                    view: &view,
                    n_samples: u.n_samples,
                    train_loss: u.train_loss,
                    update_var: u.update_var,
                })
                .unwrap();
        }
        let want = serial.finalize(&global, &mut SgdServer).unwrap();
        for (shards, workers) in [(1, 1), (2, 2), (3, 2), (7, 4), (n_threads(), n_threads())] {
            let pool = Arc::new(ShardPool::new(workers, shards));
            let mut sharded = RoundAggregator::with_ingest(
                strategy.clone(),
                p,
                Arc::new(ScratchPool::new()),
                Some(pool),
            );
            assert!(sharded.ingest_sharded(), "streaming strategy must shard");
            for &c in &order {
                let u = &updates[c];
                let payload = SharedDecoded::new(u.enc.clone(), p).unwrap();
                sharded
                    .fold_shared(&SharedInput {
                        client: c as u32,
                        payload: Arc::new(payload),
                        n_samples: u.n_samples,
                        train_loss: u.train_loss,
                        update_var: u.update_var,
                    })
                    .unwrap();
            }
            let got = sharded.finalize(&global, &mut SgdServer).unwrap();
            for (j, (x, y)) in want.new_params.iter().zip(&got.new_params).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{strat:?}/{cfg:?} shards={shards} workers={workers} diverged at coord {j}"
                );
            }
            assert_eq!(want.weights, got.weights, "shards={shards}");
            assert_eq!(
                want.mean_train_loss.to_bits(),
                got.mean_train_loss.to_bits(),
                "shards={shards}"
            );
        }
    });
}

/// The empty-update regression (`k_of` satellite): compression of a
/// zero-length vector must not panic for any config, and must round-
/// trip through decompress and the view.
#[test]
fn prop_empty_update_never_panics() {
    use fedhpc::compress::DecodedView;
    check("empty update", 60, |g| {
        let cfg = any_compression(g);
        let enc = compress(&[], &cfg, g.rng.next_u64());
        assert_eq!(enc.dense_len(), 0);
        assert!(decompress(&enc, 0).unwrap().is_empty());
        assert_eq!(DecodedView::of(&enc, 0).unwrap().nnz(), 0);
    });
}

/// ISSUE 5 satellite property: every registered planner returns
/// `k.min(available)` *distinct* ids drawn from `available`, with a
/// per-client [`DispatchPlan`] for exactly the cohort — plans within
/// the defaults' bounds (epochs in [1, default], positive deadline,
/// top-k in (0, 1]).
#[test]
fn prop_every_planner_returns_k_distinct_planned_clients() {
    check("planner", 200, |g| {
        let n = g.usize_in(1, 80) as u32;
        let mut reg = ClientRegistry::new();
        for i in 0..n {
            reg.register(
                i,
                ClientProfile {
                    speed_factor: g.f64_in(0.01, 2.0),
                    mem_gb: 16.0,
                    link_bw: g.f64_in(1e7, 1e10),
                    n_samples: g.usize_in(10, 1000) as u64,
                    bench_step_ms: g.f64_in(1.0, 500.0),
                },
            );
            // random history
            for r in 0..g.usize_in(0, 5) as u32 {
                if g.bool() {
                    reg.report_success(i, r, g.f64_in(10.0, 10_000.0));
                } else {
                    reg.report_failure(i, r);
                }
            }
        }
        let avail: Vec<u32> = (0..n).filter(|_| g.bool()).collect();
        let k = g.usize_in(1, 40);
        let explore = g.f64_in(0.0, 1.0);
        let exclude = g.f64_in(1.5, 10.0);
        let specs = ["random", "adaptive", "tiered:2", "tiered:5", "deadline", "deadline:750"];
        let spec = (*g.pick(&specs)).to_string();
        let spec = if spec == "adaptive" {
            format!("adaptive:{explore}:{exclude}")
        } else {
            spec
        };
        let defaults = DispatchPlan {
            deadline_ms: *g.pick(&[500u64, 5_000, 60_000]),
            local_epochs: g.usize_in(1, 8) as u32,
            compression: any_compression(g),
        };
        let ctx = PlanContext {
            round: g.usize_in(0, 50) as u32,
            k,
            defaults,
        };
        let mut planner = planner_by_name(&spec).unwrap();
        let plan = planner.plan(&mut reg, &avail, &ctx, &mut g.rng);
        // invariants: exactly k.min(avail) members, distinct, all from
        // available, each with a plan inside the defaults' bounds
        assert_eq!(plan.len(), k.min(avail.len()), "{spec}");
        let sel = plan.cohort().to_vec();
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), sel.len(), "{spec}: duplicate selection");
        for &id in &sel {
            assert!(avail.contains(&id), "{spec}: unavailable client {id}");
            let p = plan.get(id).unwrap_or_else(|| panic!("{spec}: member {id} without a plan"));
            assert!(
                (1..=defaults.local_epochs).contains(&p.local_epochs),
                "{spec}: epochs {} outside [1, {}]",
                p.local_epochs,
                defaults.local_epochs
            );
            assert!(p.deadline_ms > 0, "{spec}: zero deadline");
            assert!(
                p.compression.topk_frac > 0.0 && p.compression.topk_frac <= 1.0,
                "{spec}: topk {}",
                p.compression.topk_frac
            );
        }
    });
}

#[test]
fn prop_message_roundtrip_with_random_compression() {
    check("message roundtrip", 200, |g| {
        let v = g.f32_vec(1500);
        let cfg = any_compression(g);
        let delta = compress(&v, &cfg, g.rng.next_u64());
        let msg = Msg::Update {
            round: g.usize_in(0, 1000) as u32,
            client: g.usize_in(0, 500) as u32,
            base_version: g.usize_in(0, 1000) as u32,
            delta,
            stats: UpdateStats {
                n_samples: g.usize_in(0, 100_000) as u64,
                train_loss: g.f32_in(0.0, 100.0),
                steps: g.usize_in(0, 10_000) as u32,
                compute_ms: g.f64_in(0.0, 1e6),
                update_var: g.f32_in(0.0, 10.0),
            },
        };
        let enc = msg.encode();
        assert_eq!(Msg::decode(&enc).unwrap(), msg);
        // truncations never panic
        let cut = g.usize_in(0, enc.len());
        let _ = Msg::decode(&enc[..cut]);
    });
}

/// ISSUE 4 satellite: in the fixed-point domain, pairwise masks cancel
/// *exactly* under summation for any subset-free (full-participation)
/// round — the masked aggregate is bit-identical to the unmasked
/// fixed-point FedAvg over the same updates, for random participant
/// counts, parameter sizes, session seeds and (nasty) values.
#[test]
fn prop_secure_masking_fixed_point_is_bit_identical_to_unmasked() {
    use fedhpc::secure::SecureAggregator;
    check("secure masking fixed", 100, |g| {
        let p = g.usize_in(1, 400);
        let k = g.usize_in(2, 9);
        let agg = SecureAggregator::new(g.rng.next_u64(), p);
        let raw: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                // bounded nasty values: the fixed-point domain covers
                // |x| ≤ ~1e4 with headroom (see FIXED_SCALE docs)
                let mut v = g.f32_vec_nasty(p);
                v.resize(p, 0.0);
                for x in &mut v {
                    *x = x.clamp(-1e4, 1e4);
                }
                v
            })
            .collect();
        let participants: Vec<u32> = (0..k as u32).collect();
        let masked: Vec<Vec<u64>> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| agg.mask_fixed(i as u32, u, &participants))
            .collect();
        let views: Vec<&[u64]> = masked.iter().map(|v| v.as_slice()).collect();
        let got = agg.aggregate_fixed(&views);
        let raws: Vec<&[f32]> = raw.iter().map(|v| v.as_slice()).collect();
        let want = agg.aggregate_fixed_unmasked(&raws);
        for (j, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "coord {j}: masked {a} != unmasked {b}"
            );
        }
        // and the fixed-point mean tracks the exact mean within the
        // quantization bound: the sum of k per-client rounding errors
        // (each ≤ 1/2 ulp at 2^-24), divided by k
        for j in 0..p {
            let exact: f64 = raw.iter().map(|u| u[j] as f64).sum::<f64>() / k as f64;
            let err = (want[j] as f64 - exact).abs();
            let bound = 0.5 / (1u64 << 24) as f64 + exact.abs() * 1e-6 + 1e-6;
            assert!(err <= bound, "coord {j}: err {err} > {bound}");
        }
    });
}

#[test]
fn prop_secure_masking_cancels() {
    use fedhpc::secure::{MaskedUpdate, SecureAggregator};
    check("secure masking", 100, |g| {
        let p = g.usize_in(1, 300);
        let k = g.usize_in(2, 8);
        let agg = SecureAggregator::new(g.rng.next_u64(), p);
        let raw: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect())
            .collect();
        let participants: Vec<u32> = (0..k as u32).collect();
        let masked: Vec<MaskedUpdate> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| MaskedUpdate {
                client: i as u32,
                values: agg.mask(i as u32, u, &participants),
                weight: 1.0,
            })
            .collect();
        let got = agg.aggregate(&masked);
        for j in 0..p {
            let want: f64 =
                raw.iter().map(|u| u[j] as f64).sum::<f64>() / k as f64;
            assert!(
                (got[j] as f64 - want).abs() < 1e-3,
                "coord {j}: {} vs {want}",
                got[j]
            );
        }
    });
}
