//! PJRT integration: the real three-layer path. Requires AOT
//! artifacts (`make artifacts`); every test is skipped with a notice
//! when they are absent so `cargo test` stays green pre-build.

use fedhpc::data::{Batch, FederatedDataset};
use fedhpc::runtime::{Manifest, ModelRuntime, PjrtRuntime};
use fedhpc::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(dir.to_string())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn batch_for(rt: &PjrtRuntime, kind: &str, seed: u64) -> Batch {
    let info = rt.info();
    let n = if kind == "train" {
        info.train_batch
    } else {
        info.eval_batch
    };
    let mut rng = Rng::new(seed);
    let x_len: usize = info.x_shape.iter().product::<usize>().max(1);
    let y_len: usize = info.y_shape.iter().product::<usize>().max(1);
    let x: Vec<f32> = if info.x_dtype == "i32" {
        (0..n * x_len).map(|_| rng.below(50) as f32).collect()
    } else {
        (0..n * x_len).map(|_| rng.normal() as f32).collect()
    };
    let y: Vec<i32> = (0..n * y_len).map(|_| rng.below(10) as i32).collect();
    Batch { x, y, n }
}

#[test]
fn manifest_lists_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in ["cifar_cnn", "charlm", "medmnist_mlp", "e2e_charlm"] {
        assert!(m.models.contains_key(name), "missing {name}");
    }
}

#[test]
fn mlp_init_train_eval_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir, "medmnist_mlp").unwrap();
    assert_eq!(rt.n_params(), 235_146);
    let p0 = rt.init(7).unwrap();
    assert_eq!(p0.len(), rt.n_params());
    assert!(p0.iter().all(|v| v.is_finite()));
    // deterministic init
    assert_eq!(rt.init(7).unwrap(), p0);
    assert_ne!(rt.init(8).unwrap(), p0);

    let batch = batch_for(&rt, "train", 1);
    let out = rt.train_step(&p0, &p0, &batch, 0.05, 0.0).unwrap();
    assert_eq!(out.params.len(), p0.len());
    assert!(out.loss > 0.0 && out.loss.is_finite());
    assert!(out.correct >= 0.0 && out.correct <= batch.n as f32);
    assert_ne!(out.params, p0, "train step must move params");

    let eval_batch = batch_for(&rt, "eval", 2);
    let e = rt.eval_step(&p0, &eval_batch).unwrap();
    assert!(e.loss_sum > 0.0);
    assert_eq!(e.n, eval_batch.n as u64);
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir, "medmnist_mlp").unwrap();
    let mut params = rt.init(0).unwrap();
    let global = params.clone();
    let batch = batch_for(&rt, "train", 3);
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..6 {
        let out = rt.train_step(&params, &global, &batch, 0.05, 0.0).unwrap();
        params = out.params;
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(
        last < first.unwrap(),
        "loss should fall on a fixed batch: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn fedprox_mu_pulls_toward_global() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir, "medmnist_mlp").unwrap();
    let p0 = rt.init(1).unwrap();
    let batch = batch_for(&rt, "train", 4);
    // drift one step, then compare mu=0 vs large mu
    let drifted = rt.train_step(&p0, &p0, &batch, 0.05, 0.0).unwrap().params;
    let free = rt.train_step(&drifted, &p0, &batch, 0.05, 0.0).unwrap().params;
    let prox = rt
        .train_step(&drifted, &p0, &batch, 0.05, 50.0)
        .unwrap()
        .params;
    let dist = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    assert!(
        dist(&prox, &p0) < dist(&free, &p0),
        "prox should stay closer to global"
    );
}

#[test]
fn charlm_sequence_model_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir, "charlm").unwrap();
    let p = rt.init(2).unwrap();
    let batch = batch_for(&rt, "train", 5);
    let out = rt.train_step(&p, &p, &batch, 0.1, 0.0).unwrap();
    assert!(out.loss > 0.0);
    // LM counts label positions: batch × seq
    assert_eq!(rt.samples_per_example(), 32);
    let e = rt.eval_step(&p, &batch_for(&rt, "eval", 6)).unwrap();
    assert_eq!(e.n, (rt.eval_batch() * 32) as u64);
    // untrained char-LM loss ≈ ln(64) ≈ 4.16
    let mean = e.mean_loss();
    assert!((2.0..6.0).contains(&mean), "LM init loss {mean}");
}

#[test]
fn pjrt_runtime_is_shareable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir, "medmnist_mlp").unwrap();
    let p0 = rt.init(0).unwrap();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let rt = rt.clone();
        let p = p0.clone();
        handles.push(std::thread::spawn(move || {
            let batch = batch_for(&rt, "train", 10 + t);
            rt.train_step(&p, &p, &batch, 0.05, 0.0).unwrap().loss
        }));
    }
    for h in handles {
        assert!(h.join().unwrap().is_finite());
    }
}

#[test]
fn real_federation_small_pjrt_run() {
    // the full stack on real artifacts: 4 clients, 2 rounds
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = fedhpc::config::presets::quickstart();
    cfg.name = "it_pjrt_fed".into();
    cfg.artifacts_dir = dir;
    cfg.mock_runtime = false;
    cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 4)];
    cfg.selection.clients_per_round = 3;
    cfg.train.rounds = 2;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    let rep = fedhpc::experiments::run_real(&cfg).unwrap();
    assert_eq!(rep.rounds.len(), 2);
    for r in &rep.rounds {
        assert!(r.reported > 0);
        assert!(r.train_loss.is_finite());
    }
    let _ = FederatedDataset::build(&cfg.data, 4, cfg.seed).unwrap();
}
