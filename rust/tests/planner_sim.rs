//! Planner acceptance regressions (ISSUE 5): deterministic virtual-time
//! evidence that heterogeneity-aware cohort planning pays off.
//!
//! The scenario is the PR 4 straggler setup (50% of dispatches straggle
//! 4×) on a genuinely heterogeneous fleet — half `hpc-rtx6000`
//! (speed 1.0), half `hpc-cpu` (speed 0.08, ~12× slower) — under a
//! round deadline that fast clients always make and slow clients at
//! the full epoch budget never do:
//!
//! * `random` dispatches everyone identically → every slow client
//!   misses the deadline every round (dropped work, wasted downlink);
//! * `tiered:2` gives the slow tier ~¼-to-floor epoch budgets from its
//!   EWMA slowdown → slow clients land inside the same deadline and
//!   contribute, so deadline misses collapse while final accuracy
//!   stays equal-or-better (more of the fleet's data participates).
//!
//! Determinism is pinned run-twice: same seed ⇒ identical per-round
//! reporter sets, durations and final model hash for every planner.

use fedhpc::config::presets::quickstart;
use fedhpc::config::{Partition, PlannerKind};
use fedhpc::experiments::{run_sim, SimTiming};

/// Heterogeneous straggler scenario: see the module docs for the
/// timing budget that makes 1.8 s the fast/slow discriminator.
fn hetero_cfg(name: &str) -> fedhpc::config::ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.into();
    cfg.mock_runtime = true;
    cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 4), ("hpc-cpu".into(), 4)];
    cfg.selection.clients_per_round = 8;
    cfg.train.rounds = 12;
    cfg.train.lr = 0.2;
    cfg.train.local_epochs = 4;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.data.partition = Partition::Iid;
    // the PR 4 straggler scenario
    cfg.faults.straggler_prob = 0.5;
    cfg.faults.straggler_factor = 4.0;
    cfg.straggler.deadline_ms = Some(1_800);
    cfg.straggler.partial_k = None;
    cfg
}

/// ISSUE 5 acceptance: `tiered` cuts deadline misses versus `random`
/// under 4× stragglers on a heterogeneous fleet, at equal-or-better
/// final accuracy.
#[test]
fn tiered_cuts_deadline_misses_vs_random_without_losing_accuracy() {
    let mut random_cfg = hetero_cfg("planner_random");
    random_cfg.selection.planner = Some(PlannerKind::Random);
    let random = run_sim(&random_cfg, &SimTiming::default(), true).unwrap();

    let mut tiered_cfg = hetero_cfg("planner_tiered");
    tiered_cfg.selection.planner = Some(PlannerKind::Tiered { tiers: 2 });
    let tiered = run_sim(&tiered_cfg, &SimTiming::default(), true).unwrap();

    let misses = |r: &fedhpc::experiments::SimReport| -> u32 {
        r.report.rounds.iter().map(|m| m.deadline_misses).sum()
    };
    let (m_random, m_tiered) = (misses(&random), misses(&tiered));
    // sanity: the scenario genuinely stresses the deadline under random
    assert!(
        m_random >= random_cfg.train.rounds as u32,
        "random should be missing deadlines constantly, got {m_random}"
    );
    // the claim: tiered dispatch absorbs the slow tier instead of
    // dropping it
    assert!(
        m_tiered < m_random,
        "tiered did not reduce deadline misses ({m_tiered} vs {m_random})"
    );
    assert!(
        (m_tiered as f64) <= 0.8 * (m_random as f64),
        "tiered only marginally reduced misses ({m_tiered} vs {m_random})"
    );
    // slow clients now actually contribute updates
    let reported = |r: &fedhpc::experiments::SimReport| -> u32 {
        r.report.rounds.iter().map(|m| m.reported).sum()
    };
    assert!(
        reported(&tiered) > reported(&random),
        "tiered should aggregate more of the fleet ({} vs {})",
        reported(&tiered),
        reported(&random)
    );
    // ...without losing final accuracy (more data in, fewer epochs on
    // the slow half)
    let acc_random = random.report.final_accuracy().unwrap();
    let acc_tiered = tiered.report.final_accuracy().unwrap();
    assert!(
        acc_tiered >= acc_random - 0.05,
        "tiered lost accuracy: {acc_tiered:.3} vs random {acc_random:.3}"
    );
}

/// Same seed ⇒ identical cohorts, per-client plans, reporter sets,
/// virtual times and final model hash — for every planner that ships.
#[test]
fn planner_sims_replay_bit_identically() {
    for (tag, planner) in [
        ("random", PlannerKind::Random),
        ("tiered", PlannerKind::Tiered { tiers: 2 }),
        (
            "deadline",
            PlannerKind::Deadline {
                target_ms: Some(1_800),
            },
        ),
        (
            "adaptive",
            PlannerKind::Adaptive {
                explore_frac: 0.2,
                exclude_factor: 2.5,
            },
        ),
    ] {
        let mut cfg = hetero_cfg(&format!("planner_det_{tag}"));
        cfg.train.rounds = 6;
        cfg.selection.planner = Some(planner);
        let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
        let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();
        assert_eq!(a.details, b.details, "{tag}: reporter sets diverged");
        assert_eq!(a.model_hash, b.model_hash, "{tag}: model hash diverged");
        assert!(a.model_hash.is_some());
        assert_eq!(
            a.total_time_s.to_bits(),
            b.total_time_s.to_bits(),
            "{tag}: durations diverged"
        );
        // a different seed produces a different trajectory
        cfg.seed += 1;
        let c = run_sim(&cfg, &SimTiming::default(), true).unwrap();
        assert_ne!(a.details, c.details, "{tag}: seed had no effect");
    }
}

/// The tiered planner also drives the buffered-async virtual engine:
/// per-client epoch budgets ride along dispatches, the run stays
/// deterministic, and every commit still closes on `buffer_k` folds.
#[test]
fn tiered_planner_drives_the_async_engine_deterministically() {
    let mut cfg = hetero_cfg("planner_async_tiered");
    cfg.train.rounds = 8; // commits
    cfg.selection.planner = Some(PlannerKind::Tiered { tiers: 2 });
    cfg.round_mode = fedhpc::config::RoundMode::BufferedAsync {
        buffer_k: 3,
        max_staleness: 50,
        staleness: fedhpc::config::StalenessFn::Polynomial { alpha: 0.5 },
    };
    let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    assert_eq!(a.details, b.details);
    assert_eq!(a.model_hash, b.model_hash);
    assert_eq!(a.report.rounds.len(), 8);
    for (r, d) in a.report.rounds.iter().zip(&a.details) {
        assert_eq!(r.reported, 3, "commit {} did not close on buffer_k", r.round);
        assert_eq!(d.reporters.len(), 3);
    }
}
