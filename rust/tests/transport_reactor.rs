//! Regression + scale tests for the readiness-driven TCP transport.
//!
//! Each of the first four tests pins one structural bug of the old
//! thread-per-connection server (they fail against that design):
//!
//! 1. head-of-line blocking — `send_to` held the global peer lock
//!    across a blocking socket write, so one stalled client delayed
//!    sends to every healthy peer;
//! 2. re-registration race — the replaced connection's reader removed
//!    the *new* stream from the peer map and decremented the gauge;
//! 3. gauge/peer-map leak — reader exits that skipped deregistration;
//! 4. traffic misaccounting — bytes recorded before the write could
//!    fail, and the 4-byte frame header never counted.
//!
//! The rest exercise the new layer at scale: a 512-connection round,
//! slowloris reaping, outbox backpressure, and v2 compression interop.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use fedhpc::compress::Encoded;
use fedhpc::config::{CompressionConfig, TransportConfig};
use fedhpc::network::framing;
use fedhpc::network::tcp::{TcpClient, TcpServer};
use fedhpc::network::transport::{ClientTransport, ServerTransport};
use fedhpc::network::{pre_encode_dense, ClientProfile, LinkShaper, Msg, TrafficLog, UpdateStats};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn profile() -> ClientProfile {
    ClientProfile {
        speed_factor: 1.0,
        mem_gb: 1.0,
        link_bw: 1e9,
        n_samples: 10,
        bench_step_ms: 1.0,
    }
}

fn register(id: u32) -> Msg {
    Msg::Register {
        client: id,
        profile: profile(),
    }
}

/// Connect a raw blocking socket and send an (uncompressed) Register.
fn raw_register(addr: &str, id: u32) -> TcpStream {
    let mut sock = TcpStream::connect(addr).unwrap();
    let frame = framing::build_frame(&register(id).encode(), None, false).unwrap();
    framing::write_frame(&mut sock, &frame).unwrap();
    sock
}

fn wait_until(mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < Duration::from_secs(10) {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    false
}

fn round_end(round: u32, model_version: u32) -> Msg {
    Msg::RoundEnd {
        round,
        model_version,
    }
}

/// A big, compressible server→client broadcast message.
fn big_round_start(n: usize) -> Msg {
    let params: Vec<f32> = vec![0.125f32; n];
    Msg::RoundStart {
        round: 1,
        model_version: 1,
        deadline_ms: 1_000,
        lr: 0.1,
        mu: 0.0,
        local_epochs: 1,
        params: Encoded::PreEncoded(pre_encode_dense(&params)),
        mask_seed: 0,
        compression: CompressionConfig::NONE,
    }
}

fn update_msg(id: u32, n: usize) -> Msg {
    Msg::Update {
        round: 1,
        client: id,
        base_version: 1,
        delta: Encoded::Dense((0..n).map(|i| i as f32).collect()),
        stats: UpdateStats {
            n_samples: 1,
            train_loss: 0.0,
            steps: 1,
            compute_ms: 0.0,
            update_var: 0.0,
        },
    }
}

/// Bug 1 (head-of-line blocking): a peer that stops draining its socket
/// must only poison its *own* sends — a send to a healthy peer stays
/// fast. The old transport serialized every `send_to` behind the global
/// peer mutex while a blocking write to the stalled socket wedged it.
#[test]
fn stalled_peer_does_not_block_sends_to_healthy_peers() {
    let cfg = TransportConfig {
        outbox_frames: 4,
        compression: false,
        ..TransportConfig::default()
    };
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind_with("127.0.0.1:0", &cfg, traffic.clone()).unwrap();
    let addr = server.local_addr.to_string();

    // the stalled peer: registers, then never reads its socket
    let stalled = raw_register(&addr, 1);
    // the healthy peer: a real client that keeps receiving
    let healthy =
        TcpClient::connect(&addr, &register(2), LinkShaper::unshaped(), traffic).unwrap();
    for _ in 0..2 {
        server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    }

    // jam peer 1: its kernel buffer fills, then its bounded outbox
    // fills, then send_to starts failing fast — never blocking
    let big = big_round_start(64 * 1024); // ~256 KB frames
    let mut jammed = None;
    for i in 0..256 {
        if let Err(e) = server.send_to(1, &big) {
            jammed = Some((i, format!("{e:#}")));
            break;
        }
    }
    let (_, err) = jammed.expect("bounded outbox must eventually refuse");
    assert!(
        err.contains("outbox full"),
        "expected backpressure error, got: {err}"
    );

    // the healthy peer is unaffected, and the send is fast: enqueue
    // only, no socket I/O under any shared lock
    let t0 = Instant::now();
    server.send_to(2, &round_end(1, 1)).unwrap();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(500),
        "send_to(healthy) took {elapsed:?} while peer 1 is stalled"
    );
    let got = healthy.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    assert_eq!(got, round_end(1, 1));
    drop(stalled);
}

/// Bug 2 (re-registration race): when a client reconnects under the
/// same id, the replaced connection's teardown must not evict the new
/// stream from the peer map or corrupt the connection gauge.
#[test]
fn re_registering_peer_stays_reachable_on_the_new_socket() {
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
    let addr = server.local_addr.to_string();

    let mut old_sock = raw_register(&addr, 7);
    server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    assert!(wait_until(|| server.active_connections() == 1));

    // same id reconnects — the old socket must be dropped server-side
    let mut new_sock = raw_register(&addr, 7);
    server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();

    // the old socket reaches EOF (poisoned outbox ⇒ orphan dropped)
    old_sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut byte = [0u8; 1];
    let got = old_sock.read(&mut byte);
    assert!(
        matches!(got, Ok(0)) || got.is_err(),
        "old socket should be closed after replacement"
    );

    // the id keeps exactly one registered connection (old code: the
    // replaced reader's cleanup removed the NEW entry and dec'd twice)
    assert!(
        wait_until(|| server.active_connections() == 1 && server.open_connections() == 1),
        "active={} open={}",
        server.active_connections(),
        server.open_connections()
    );
    // and it is reachable through the NEW socket
    server.send_to(7, &Msg::RegisterAck { client: 7 }).unwrap();
    let (payload, _) = framing::read_frame(&mut new_sock).unwrap();
    let msg = Msg::decode(&payload).unwrap();
    assert_eq!(msg, Msg::RegisterAck { client: 7 });
    assert_eq!(server.connected(), vec![7]);
}

/// Bug 3 (gauge/map leak): every disconnect path must deregister. Churn
/// peers through normal closes and assert the counters return to zero
/// exactly (the old reader's early-return on a closed server channel
/// leaked the map entry; see also the unit test in `network::reactor`).
#[test]
fn disconnect_churn_leaves_no_gauge_or_map_residue() {
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
    let addr = server.local_addr.to_string();
    for round in 0..3 {
        let socks: Vec<TcpStream> = (0..8).map(|i| raw_register(&addr, i)).collect();
        for _ in 0..8 {
            server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        assert!(
            wait_until(|| server.active_connections() == 8),
            "round {round}: active={}",
            server.active_connections()
        );
        drop(socks);
        assert!(
            wait_until(|| server.active_connections() == 0 && server.open_connections() == 0),
            "round {round} leaked: active={} open={}",
            server.active_connections(),
            server.open_connections()
        );
        assert!(server.connected().is_empty());
    }
}

/// Bug 4 (traffic misaccounting): `TrafficLog` must record exactly the
/// bytes that cross the wire — frame header included, post-compression,
/// and only for writes that actually completed.
#[test]
fn traffic_log_matches_bytes_observed_on_the_wire() {
    // ---- downlink: count what a raw peer socket actually receives
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
    let addr = server.local_addr.to_string();
    let mut peer = raw_register(&addr, 3);
    server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();

    server.send_to(3, &Msg::RegisterAck { client: 3 }).unwrap();
    server.send_to(3, &big_round_start(50_000)).unwrap(); // compresses
    server.send_to(3, &round_end(1, 2)).unwrap();
    let mut observed = 0u64;
    for _ in 0..3 {
        let (payload, wire) = framing::read_frame(&mut peer).unwrap();
        Msg::decode(&payload).unwrap();
        observed += wire;
    }
    assert!(
        wait_until(|| traffic.totals().0 == observed),
        "recorded down {} != observed {observed}",
        traffic.totals().0
    );
    // headers are in: 3 frames can never fit in payload bytes alone
    assert!(observed > 3 * 4);

    // ---- uplink: a raw server counts what the client actually sends
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let raw_addr = listener.local_addr().unwrap().to_string();
    let sink = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut observed = 0u64;
        for _ in 0..2 {
            let (payload, wire) = framing::read_frame(&mut s).unwrap();
            Msg::decode(&payload).unwrap();
            observed += wire;
        }
        observed
    });
    let up_traffic = Arc::new(TrafficLog::new());
    let client = TcpClient::connect(
        &raw_addr,
        &register(4),
        LinkShaper::unshaped(),
        up_traffic.clone(),
    )
    .unwrap();
    client.send(&update_msg(4, 10_000)).unwrap();
    let observed_up = sink.join().unwrap();
    assert_eq!(
        up_traffic.totals().1,
        observed_up,
        "client-recorded up bytes must equal bytes on the wire"
    );
}

/// Scale: 512 concurrent registered connections complete a full
/// broadcast + reply round, and the connection counters stay exact
/// through mass disconnect.
#[test]
fn five_hundred_twelve_connections_complete_a_round() {
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
    let addr = server.local_addr.to_string();
    const N: u32 = 512;

    let mut socks: Vec<TcpStream> = (0..N).map(|i| raw_register(&addr, i)).collect();
    let mut seen = std::collections::HashSet::new();
    while seen.len() < N as usize {
        let (from, msg) = server
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .expect("missing Register at scale");
        assert!(matches!(msg, Msg::Register { .. }));
        seen.insert(from);
    }
    assert!(wait_until(|| server.active_connections() == N as usize));

    // broadcast one frame to every peer, then read it everywhere
    for id in 0..N {
        server.send_to(id, &round_end(1, 1)).unwrap();
    }
    for sock in &mut socks {
        let (payload, _) = framing::read_frame(sock).unwrap();
        assert_eq!(Msg::decode(&payload).unwrap(), round_end(1, 1));
    }

    // every peer replies; the server sees all N
    for (i, sock) in socks.iter_mut().enumerate() {
        let hb = Msg::Heartbeat {
            client: i as u32,
            round: 1,
        };
        let frame = framing::build_frame(&hb.encode(), None, false).unwrap();
        framing::write_frame(sock, &frame).unwrap();
    }
    let mut replies = 0usize;
    while replies < N as usize {
        let (_, msg) = server
            .recv_timeout(Duration::from_secs(10))
            .unwrap()
            .expect("missing Heartbeat at scale");
        assert!(matches!(msg, Msg::Heartbeat { .. }));
        replies += 1;
    }

    drop(socks);
    assert!(
        wait_until(|| server.active_connections() == 0 && server.open_connections() == 0),
        "teardown leaked: active={} open={}",
        server.active_connections(),
        server.open_connections()
    );
}

/// Slowloris: a registered peer that sends half a frame header and goes
/// silent is reaped by the idle timeout — without wedging the reactor
/// or disturbing healthy peers.
#[test]
fn slowloris_half_frame_is_reaped_without_wedging_the_reactor() {
    let cfg = TransportConfig {
        idle_timeout_ms: 300,
        ..TransportConfig::default()
    };
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind_with("127.0.0.1:0", &cfg, traffic.clone()).unwrap();
    let addr = server.local_addr.to_string();

    let healthy =
        TcpClient::connect(&addr, &register(2), LinkShaper::unshaped(), traffic).unwrap();
    let mut loris = raw_register(&addr, 9);
    for _ in 0..2 {
        server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    }
    assert!(wait_until(|| server.active_connections() == 2));

    // half a frame header, then silence
    loris.write_all(&[0xAB, 0x00]).unwrap();
    assert!(
        wait_until(|| server.active_connections() == 1 && server.open_connections() == 1),
        "slowloris not reaped: active={} open={}",
        server.active_connections(),
        server.open_connections()
    );

    // the reactor still serves the healthy peer
    server.send_to(2, &round_end(3, 1)).unwrap();
    let got = healthy.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    assert_eq!(got, round_end(3, 1));
    let gone = server.send_to(9, &Msg::Shutdown);
    assert!(gone.is_err(), "loris must be gone");
}

/// Interop: a peer that registered with protocol v2 must never receive
/// a compressed frame, while a v3 peer on the same server does — the
/// compression flag is strictly opt-in by negotiated version.
#[test]
fn v2_peers_never_receive_compressed_frames() {
    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic).unwrap();
    let addr = server.local_addr.to_string();

    // v2 peer: rewrite the version byte of an otherwise-identical
    // Register (v2 layout is byte-compatible)
    let mut legacy = TcpStream::connect(&addr).unwrap();
    let mut reg = register(5).encode();
    *reg.first_mut().unwrap() = 2;
    let frame = framing::build_frame(&reg, None, false).unwrap();
    framing::write_frame(&mut legacy, &frame).unwrap();
    server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();

    // v3 peer for contrast (encode() emits the current version)
    let mut modern = raw_register(&addr, 6);
    server.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();

    let big = big_round_start(50_000);
    server.send_to(5, &big).unwrap();
    server.send_to(6, &big).unwrap();

    let read_header = |sock: &mut TcpStream| -> (u32, Vec<u8>) {
        let mut hdr = [0u8; 4];
        sock.read_exact(&mut hdr).unwrap();
        let word = u32::from_le_bytes(hdr);
        let len = (word & !framing::COMPRESSED_FLAG) as usize;
        let mut body = vec![0u8; len];
        sock.read_exact(&mut body).unwrap();
        (word, body)
    };

    let (word, body) = read_header(&mut legacy);
    assert_eq!(
        word & framing::COMPRESSED_FLAG,
        0,
        "v2 peer got a compressed frame"
    );
    Msg::decode(&body).expect("v2 peer reads the plain frame");

    let (word, body) = read_header(&mut modern);
    assert_ne!(
        word & framing::COMPRESSED_FLAG,
        0,
        "v3 peer should get the compressed broadcast"
    );
    let logical = framing::unframe(&body, true).unwrap();
    Msg::decode(&logical).expect("compressed frame decodes");
    // and the compressed broadcast is genuinely smaller than the raw one
    assert!((body.len() as u64) < logical.len() as u64);
}
