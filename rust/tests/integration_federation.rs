//! Integration tests: full federations (orchestrator + worker threads +
//! transport + aggregation + metrics) over the mock runtime, covering
//! every coordinator feature the paper claims. No artifacts required.

use fedhpc::config::{
    presets::quickstart, Aggregation, CompressionConfig, Partition, SelectionPolicy,
    ServerOptKind, StragglerConfig, WeightScheme,
};
use fedhpc::experiments::run_real;

fn base_cfg(name: &str) -> fedhpc::config::ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.into();
    cfg.mock_runtime = true;
    cfg.train.rounds = 5;
    cfg.train.local_epochs = 1;
    cfg.train.lr = 0.2;
    cfg.data.samples_per_client = 96;
    cfg.data.eval_samples = 256;
    cfg.selection.clients_per_round = 4;
    cfg
}

#[test]
fn fedavg_noniid_learns() {
    let mut cfg = base_cfg("it_fedavg");
    cfg.data.partition = Partition::LabelShard {
        classes_per_client: 3,
    };
    let rep = run_real(&cfg).unwrap();
    assert_eq!(rep.rounds.len(), 5);
    assert!(rep.final_accuracy().unwrap() > 0.3, "non-IID FedAvg should beat chance");
    // loss should drop from round 0 to the last round
    let first = rep.rounds.first().unwrap().train_loss;
    let last = rep.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn fedprox_beats_or_matches_fedavg_under_extreme_noniid() {
    let run = |agg: Aggregation, seed: u64| {
        let mut cfg = base_cfg("it_prox_vs_avg");
        cfg.seed = seed;
        cfg.train.rounds = 8;
        cfg.data.partition = Partition::LabelShard {
            classes_per_client: 2,
        };
        cfg.aggregation = agg;
        run_real(&cfg).unwrap().best_accuracy().unwrap()
    };
    // average over seeds to damp run-to-run noise
    let seeds = [1u64, 2, 3];
    let avg: f64 = seeds.iter().map(|&s| run(Aggregation::FedAvg, s)).sum::<f64>() / 3.0;
    let prox: f64 = seeds
        .iter()
        .map(|&s| run(Aggregation::FedProx { mu: 0.1 }, s))
        .sum::<f64>()
        / 3.0;
    // paper Table 2: FedProx ≥ FedAvg under non-IID; allow small noise
    assert!(
        prox >= avg - 0.05,
        "FedProx {prox:.3} should not trail FedAvg {avg:.3} badly"
    );
}

#[test]
fn weighted_aggregation_variants_run() {
    for scheme in [WeightScheme::DataSize, WeightScheme::InverseLoss, WeightScheme::InverseVariance]
    {
        let mut cfg = base_cfg("it_weighted");
        cfg.aggregation = Aggregation::Weighted(scheme);
        cfg.train.rounds = 3;
        let rep = run_real(&cfg).unwrap();
        assert!(rep.final_accuracy().is_some());
    }
}

/// New strategy API end to end: robust aggregation and server
/// optimizers are selected *by name* (the registry strings a config
/// file carries), survive a JSON round-trip, and drive a full
/// federation over real threads + transport.
#[test]
fn strategies_selectable_by_name_from_config_run_end_to_end() {
    for (agg, opt) in [
        ("trimmed_mean:0.25", "sgd"),
        ("coordinate_median", "fedavgm:0.3"),
        ("fedavg", "fedadam:0.1"),
    ] {
        let mut cfg = base_cfg("it_strategy_by_name");
        cfg.name = format!("it_{}_{}", agg.replace(':', "_"), opt.replace(':', "_"));
        cfg.train.rounds = 3;
        cfg.aggregation = Aggregation::parse(agg).unwrap();
        cfg.server_opt = ServerOptKind::parse(opt).unwrap();
        // prove the selection survives the config-file path
        let cfg = fedhpc::config::from_json_str(&fedhpc::config::to_json(&cfg)).unwrap();
        assert_eq!(cfg.aggregation.name(), agg.split(':').next().unwrap());
        assert_eq!(cfg.server_opt.name(), opt.split(':').next().unwrap());
        let rep = run_real(&cfg).unwrap();
        assert_eq!(rep.rounds.len(), 3, "{agg}/{opt} federation died early");
        assert!(
            rep.final_accuracy().is_some(),
            "{agg}/{opt} produced no accuracy"
        );
    }
}

/// ISSUE 4 tentpole, end to end over real threads + transport: the
/// buffered-async engine (`--round-mode async_fedbuff`) drives a full
/// federation — workers report their base model version, the server
/// folds regardless of round tag and commits every `buffer_k` folds —
/// and the selection survives the config-file path.
#[test]
fn async_fedbuff_round_mode_runs_a_real_federation() {
    let mut cfg = base_cfg("it_async_fedbuff");
    cfg.train.rounds = 5; // commits in async mode
    cfg.straggler.deadline_ms = Some(30_000); // per-commit guard, never hit
    cfg.round_mode = fedhpc::config::RoundMode::parse("async_fedbuff:2:0.5:100").unwrap();
    // prove the mode survives the config-file path
    let cfg = fedhpc::config::from_json_str(&fedhpc::config::to_json(&cfg)).unwrap();
    assert!(cfg.round_mode.is_async());
    let rep = run_real(&cfg).unwrap();
    assert_eq!(rep.rounds.len(), 5, "async federation died early");
    for r in &rep.rounds {
        assert_eq!(r.reported, 2, "every commit closes on buffer_k folds");
    }
    assert!(rep.final_accuracy().is_some());
}

/// FedAvgM momentum across a real federation still learns (momentum
/// state carried on the orchestrator between rounds).
#[test]
fn fedavgm_server_momentum_federation_learns() {
    let mut cfg = base_cfg("it_fedavgm");
    cfg.data.partition = Partition::Iid;
    cfg.train.rounds = 6;
    cfg.server_opt = ServerOptKind::FedAvgM { beta: 0.3 };
    let rep = run_real(&cfg).unwrap();
    assert!(
        rep.final_accuracy().unwrap() > 0.3,
        "momentum federation should beat chance, got {:?}",
        rep.final_accuracy()
    );
}

#[test]
fn compression_cuts_upload_without_killing_accuracy() {
    let mut dense = base_cfg("it_comp_dense");
    dense.train.rounds = 6;
    let rep_dense = run_real(&dense).unwrap();

    let mut comp = base_cfg("it_comp_paper");
    comp.train.rounds = 6;
    comp.compression = CompressionConfig::PAPER;
    let rep_comp = run_real(&comp).unwrap();

    let up_dense = rep_dense.mean_upload_per_round();
    let up_comp = rep_comp.mean_upload_per_round();
    assert!(
        up_comp < up_dense * 0.45,
        "paper codec should cut >55%: {up_comp} vs {up_dense}"
    );
    let acc_dense = rep_dense.best_accuracy().unwrap();
    let acc_comp = rep_comp.best_accuracy().unwrap();
    assert!(
        acc_comp > acc_dense - 0.15,
        "compression cost too much accuracy: {acc_comp} vs {acc_dense}"
    );
}

#[test]
fn federated_dropout_roundtrips_through_the_stack() {
    let mut cfg = base_cfg("it_fed_dropout");
    cfg.compression = CompressionConfig {
        quant_bits: 32,
        topk_frac: 1.0,
        dropout_keep: 0.5,
    };
    cfg.train.rounds = 4;
    let rep = run_real(&cfg).unwrap();
    assert!(rep.final_accuracy().unwrap() > 0.25);
    // upload must be roughly halved (indices regenerate from seed)
    let dense_bytes = 4.0 * (784 * 10 + 10) as f64 * cfg.selection.clients_per_round as f64;
    assert!(rep.mean_upload_per_round() < dense_bytes * 0.8);
}

#[test]
fn partial_k_and_deadline_complete_rounds_with_stragglers() {
    let mut cfg = base_cfg("it_partial_k");
    cfg.faults.straggler_prob = 0.5;
    cfg.faults.straggler_factor = 8.0;
    cfg.straggler = StragglerConfig {
        deadline_ms: Some(8_000),
        partial_k: Some(2),
    };
    cfg.train.rounds = 4;
    let rep = run_real(&cfg).unwrap();
    for r in &rep.rounds {
        assert!(r.reported >= 1, "round {} starved", r.round);
    }
    assert!(rep.final_accuracy().unwrap() > 0.2);
}

#[test]
fn dropouts_degrade_gracefully() {
    // paper §5.4: 20% dropouts -> <1.8pp accuracy drop (we allow more
    // noise at this tiny scale but the run must complete and learn)
    let mut cfg = base_cfg("it_dropouts");
    cfg.faults.dropout_prob = 0.2;
    cfg.train.rounds = 6;
    cfg.straggler.deadline_ms = Some(10_000);
    let rep = run_real(&cfg).unwrap();
    let dropped: u32 = rep.rounds.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "injector produced no dropouts");
    assert!(rep.final_accuracy().unwrap() > 0.3);
}

#[test]
fn random_vs_adaptive_selection_both_work() {
    for policy in [SelectionPolicy::Random, SelectionPolicy::default()] {
        let mut cfg = base_cfg("it_selection");
        cfg.selection.policy = policy;
        cfg.train.rounds = 3;
        let rep = run_real(&cfg).unwrap();
        assert_eq!(rep.rounds.len(), 3);
        for r in &rep.rounds {
            assert_eq!(r.selected, 4);
        }
    }
}

#[test]
fn dirichlet_partition_federation() {
    let mut cfg = base_cfg("it_dirichlet");
    cfg.data.partition = Partition::Dirichlet { alpha: 0.3 };
    cfg.train.rounds = 4;
    let rep = run_real(&cfg).unwrap();
    assert!(rep.final_accuracy().unwrap() > 0.25);
}

#[test]
fn convergence_early_stop_on_target_accuracy() {
    let mut cfg = base_cfg("it_early_stop");
    cfg.data.partition = Partition::Iid;
    cfg.train.rounds = 30;
    cfg.train.target_accuracy = Some(0.5);
    let rep = run_real(&cfg).unwrap();
    assert!(
        rep.rounds.len() < 30,
        "should stop early once 50% accuracy is hit (ran {} rounds)",
        rep.rounds.len()
    );
    assert!(rep.converged_at.is_some());
}

#[test]
fn deterministic_replay_same_seed() {
    let cfg = base_cfg("it_replay");
    let a = run_real(&cfg).unwrap();
    let b = run_real(&cfg).unwrap();
    // accuracy trajectory identical: same selection, same batches, same
    // aggregation (wall-clock durations differ)
    let accs = |r: &fedhpc::metrics::TrainingReport| -> Vec<Option<f64>> {
        r.rounds.iter().map(|m| m.eval_accuracy).collect()
    };
    assert_eq!(accs(&a), accs(&b));
}
