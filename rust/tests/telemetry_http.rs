//! Telemetry subsystem integration tests (PR 7): golden-file /metrics
//! exposition, a live HTTP server exercised over real sockets, exact
//! totals under multi-threaded hammering, and drain-mid-run against
//! both round engines.
//!
//! All tests build PRIVATE `Registry` instances where values are
//! asserted exactly — the global registry is shared by every test in
//! the process, so its values are never pinned here.

use fedhpc::config::{presets::quickstart, Partition, RoundMode, StalenessFn};
use fedhpc::experiments::run_real_with_control;
use fedhpc::metrics::RoundMetrics;
use fedhpc::orchestrator::OrchestratorHooks;
use fedhpc::telemetry::{
    ControlCmd, ControlPlane, Registry, TelemetryServer, ROUND_SECONDS_BUCKETS,
    STALENESS_BUCKETS,
};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const GOLDEN: &str = include_str!("golden/metrics_exposition.txt");

/// A registry with one exemplar of every metric shape the production
/// inventory uses, set to fixed values.
fn golden_registry() -> Registry {
    let reg = Registry::new();
    reg.counter("fedhpc_rounds_total", "Rounds finalized.").add(3);
    reg.counter(
        "fedhpc_ingest_bytes_total",
        "Encoded update bytes folded by the server.",
    )
    .add(4096);
    for (tier, n) in [("fast", 0u64), ("mid", 1), ("slow", 2)] {
        reg.counter_with(
            "fedhpc_deadline_misses_total",
            "Deadline misses by client speed tier.",
            "tier",
            tier,
        )
        .add(n);
    }
    reg.gauge(
        "fedhpc_tcp_active_connections",
        "Registered TCP peers currently connected.",
    )
    .set(4);
    let rounds = reg.histogram(
        "fedhpc_round_duration_seconds",
        "Seconds per round.",
        ROUND_SECONDS_BUCKETS,
    );
    for v in [0.05, 0.3, 2.0] {
        rounds.observe(v);
    }
    let staleness = reg.histogram(
        "fedhpc_update_staleness",
        "Per-folded-update staleness in commits.",
        STALENESS_BUCKETS,
    );
    for v in [0.0, 0.0, 1.0, 3.0] {
        staleness.observe(v);
    }
    reg
}

#[test]
fn metrics_exposition_matches_golden_file() {
    assert_eq!(
        golden_registry().render(),
        GOLDEN,
        "exposition format drifted — if intentional, regenerate \
         rust/tests/golden/metrics_exposition.txt"
    );
}

#[test]
fn exposition_is_byte_stable_across_renders() {
    let reg = golden_registry();
    assert_eq!(reg.render(), reg.render());
}

// ---------------------------------------------------------------- //
// live server over real sockets

/// Minimal HTTP/1.1 client: one request, read to EOF (the server
/// always closes). Returns (status_code, full_response_text).
fn http(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect telemetry");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("write request");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read response");
    let code: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    (code, text)
}

#[test]
fn live_server_serves_metrics_health_ready_and_status() {
    let reg = Arc::new(Registry::new());
    reg.counter("t_live_total", "live test counter").add(7);
    let cp = Arc::new(ControlPlane::new());
    let srv = TelemetryServer::bind("127.0.0.1:0", reg.clone(), cp.clone()).unwrap();
    let addr = srv.local_addr().to_string();

    let (code, text) = http(&addr, "GET", "/metrics", "");
    assert_eq!(code, 200);
    assert!(text.contains("text/plain; version=0.0.4"));
    assert!(text.contains("t_live_total 7"));

    assert_eq!(http(&addr, "GET", "/healthz", "").0, 200);
    // not ready until the round loop marks it
    assert_eq!(http(&addr, "GET", "/readyz", "").0, 503);
    cp.mark_ready();
    assert_eq!(http(&addr, "GET", "/readyz", "").0, 200);

    cp.set_status("state=running round=5".to_string());
    let (code, text) = http(&addr, "GET", "/status", "");
    assert_eq!(code, 200);
    assert!(text.contains("state=running round=5"));

    assert_eq!(http(&addr, "GET", "/no-such-route", "").0, 404);
    srv.shutdown();
}

#[test]
fn live_server_control_verbs_roundtrip() {
    let reg = Arc::new(Registry::new());
    let cp = Arc::new(ControlPlane::new());
    let srv = TelemetryServer::bind("127.0.0.1:0", reg.clone(), cp.clone()).unwrap();
    let addr = srv.local_addr().to_string();

    let (code, text) = http(&addr, "POST", "/control", "quiesce");
    assert_eq!(code, 202, "{text}");
    let (code, _) = http(&addr, "POST", "/control", "set-planner tiered:3");
    assert_eq!(code, 202);
    // invalid spec rejected eagerly, never enqueued
    let (code, text) = http(&addr, "POST", "/control", "set-planner oracle:9");
    assert_eq!(code, 400, "{text}");
    let (code, _) = http(&addr, "POST", "/control", "definitely-not-a-verb");
    assert_eq!(code, 400);

    assert_eq!(
        cp.drain_mailbox(),
        vec![
            ControlCmd::Quiesce,
            ControlCmd::SetPlanner("tiered:3".to_string())
        ]
    );
    // accepted verbs were counted, rejected ones were not
    let text = reg.render();
    assert!(text.contains("fedhpc_control_commands_total{verb=\"quiesce\"} 1"));
    assert!(text.contains("fedhpc_control_commands_total{verb=\"set-planner\"} 1"));
    srv.shutdown();
}

#[test]
fn live_server_survives_garbage_requests() {
    let reg = Arc::new(Registry::new());
    let cp = Arc::new(ControlPlane::new());
    let srv = TelemetryServer::bind("127.0.0.1:0", reg, cp).unwrap();
    let addr = srv.local_addr().to_string();

    // raw garbage (no valid request line)
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"\r\n\r\n").unwrap();
    let mut text = String::new();
    let _ = s.read_to_string(&mut text);
    assert!(text.starts_with("HTTP/1.1 400"), "got: {text:?}");

    // the server still answers normal requests afterwards
    assert_eq!(http(&addr, "GET", "/healthz", "").0, 200);
    srv.shutdown();
}

// ---------------------------------------------------------------- //
// concurrency: relaxed atomics lose nothing

#[test]
fn hammered_registry_keeps_exact_totals() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 20_000;
    let reg = Arc::new(Registry::new());
    let c = reg.counter("t_hammer_total", "hammered counter");
    let g = reg.gauge("t_hammer_gauge", "hammered gauge");
    let h = reg.histogram("t_hammer_hist", "hammered histogram", &[0.5, 1.5]);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let (c, g, h) = (c.clone(), g.clone(), h.clone());
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    g.inc();
                    // alternate buckets deterministically per thread
                    h.observe(if (i + t as u64) % 2 == 0 { 0.25 } else { 1.0 });
                }
            })
        })
        .collect();
    for hd in handles {
        hd.join().unwrap();
    }
    let total = THREADS as u64 * PER_THREAD;
    assert_eq!(c.get(), total);
    assert_eq!(g.get(), total);
    assert_eq!(h.count(), total);
    // each thread splits its observations evenly across the 2 buckets
    assert_eq!(h.bucket_counts(), vec![total / 2, total / 2, 0]);
    let text = reg.render();
    assert!(text.contains(&format!("t_hammer_total {total}")));
    assert!(text.contains(&format!("t_hammer_hist_count {total}")));
}

// ---------------------------------------------------------------- //
// drain mid-run: both engines finish the in-flight round/commit

/// Hooks that submit `drain` right after the first completed
/// round/commit — exactly what an operator POSTing mid-run looks like
/// to the orchestrator (the command sits in the mailbox until the next
/// boundary).
struct DrainAfterFirst {
    cp: Arc<ControlPlane>,
    seen: u32,
}

impl OrchestratorHooks for DrainAfterFirst {
    fn on_round(&mut self, _m: &RoundMetrics) {
        self.seen += 1;
        if self.seen == 1 {
            self.cp.submit(ControlCmd::Drain);
        }
    }
}

fn small_cfg(name: &str) -> fedhpc::config::ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.to_string();
    cfg.mock_runtime = true;
    cfg.train.rounds = 8;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.data.partition = Partition::Iid;
    cfg
}

#[test]
fn drain_stops_sync_engine_with_complete_report() {
    let cfg = small_cfg("drain_sync");
    let cp = Arc::new(ControlPlane::new());
    let mut hooks = DrainAfterFirst {
        cp: cp.clone(),
        seen: 0,
    };
    let report = run_real_with_control(&cfg, &mut hooks, Some(cp.clone())).unwrap();
    assert!(
        !report.rounds.is_empty() && report.rounds.len() < cfg.train.rounds,
        "drain must stop early but keep finished rounds, got {} of {}",
        report.rounds.len(),
        cfg.train.rounds
    );
    // every kept round is fully populated (the in-flight round was
    // finished, not abandoned)
    for r in &report.rounds {
        assert!(r.selected > 0);
        assert!(r.duration_s >= 0.0);
        assert_eq!((r.staleness_min, r.staleness_mean, r.staleness_max), (0, 0.0, 0));
    }
    assert!(cp.is_ready(), "first dispatch must have marked readiness");
    assert!(
        cp.status_line().contains("state=draining"),
        "status after drain: {}",
        cp.status_line()
    );
}

#[test]
fn drain_stops_async_engine_with_complete_report() {
    let mut cfg = small_cfg("drain_async");
    cfg.round_mode = RoundMode::BufferedAsync {
        buffer_k: 3,
        max_staleness: 20,
        staleness: StalenessFn::Polynomial { alpha: 0.5 },
    };
    let cp = Arc::new(ControlPlane::new());
    let mut hooks = DrainAfterFirst {
        cp: cp.clone(),
        seen: 0,
    };
    let report = run_real_with_control(&cfg, &mut hooks, Some(cp)).unwrap();
    assert!(
        !report.rounds.is_empty() && report.rounds.len() < cfg.train.rounds,
        "drain must stop early but keep finished commits, got {} of {}",
        report.rounds.len(),
        cfg.train.rounds
    );
    // a commit may legitimately close empty at its deadline, but the
    // run as a whole must have folded work, and every populated commit
    // must carry a coherent staleness triple
    assert!(report.rounds.iter().any(|r| r.reported > 0));
    for r in &report.rounds {
        assert!(r.staleness_min <= r.staleness_max);
        assert!(r.staleness_mean >= f64::from(r.staleness_min));
        assert!(r.staleness_mean <= f64::from(r.staleness_max));
    }
}
