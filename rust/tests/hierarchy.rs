//! Hierarchical aggregation plane acceptance (PR 10).
//!
//! * Two-tier FedAvg is **bit-identical** to flat aggregation on dyadic
//!   inputs at a fixed per-site arrival order — the determinism
//!   contract pinned in `orchestrator::hierarchy`'s module docs —
//!   across several site splits, weight patterns and both ingest paths
//!   (serial view fold and the sharded pool).
//! * The two-tier virtual-time sim replays bit-identically run-twice,
//!   for the sync engine AND the async_fedbuff engine, and moves fewer
//!   cross-facility bytes than the equivalent flat run.
//! * A crashed (silent) site aggregator degrades gracefully: the root
//!   commits every round from the surviving site.

use fedhpc::compress::{compress, Encoded};
use fedhpc::config::presets::quickstart;
use fedhpc::config::{
    CompressionConfig, ExperimentConfig, GroupingPolicy, Partition, RoundMode, StalenessFn,
};
use fedhpc::data::FederatedDataset;
use fedhpc::experiments::{run_sim, SimTiming};
use fedhpc::network::inproc::InprocHub;
use fedhpc::network::{
    ClientProfile, ClientTransport, LinkShaper, Msg, TrafficLog, UpdateStats,
};
use fedhpc::orchestrator::{Aggregator, EvalHarness, FoldCore, NoHooks, Orchestrator};
use fedhpc::runtime::{MockRuntime, ModelRuntime};
use std::sync::Arc;
use std::time::Duration;

const N_PARAMS: usize = 512;

/// Dyadic update values (integer multiples of 2⁻⁶): exactly
/// representable in f32 and f64, so every fold/normalize/narrow step
/// in the two-tier pipeline is exact.
fn dyadic_delta(c: usize) -> Vec<f32> {
    (0..N_PARAMS)
        .map(|j| ((((c * 7 + j * 3) % 33) as i32) - 16) as f32 / 64.0)
        .collect()
}

fn stats(n: u64) -> UpdateStats {
    UpdateStats {
        n_samples: n,
        train_loss: 0.5,
        steps: 1,
        compute_ms: 1.0,
        update_var: 0.0,
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|d| d.to_bits()).collect()
}

/// Property pin: folding per-site and re-folding the site means at the
/// root reproduces the flat fold bit-for-bit, for dyadic updates and
/// power-of-two site weight masses, at a fixed (site-major) arrival
/// order — for several tree shapes and both ingest paths.
#[test]
fn two_tier_fedavg_is_bit_identical_to_flat_on_dyadic_inputs() {
    // (tag, site sizes, per-client weights). Each site's weight mass
    // sums to a power of two so the site-mean division is exact; the
    // *global* total (24 / 30 / 50) is deliberately not one — both
    // topologies divide the identical exact numerator by it.
    let splits: &[(&str, &[usize], &[u64])] = &[
        ("2x4", &[4, 4], &[1, 1, 2, 4, 2, 2, 4, 8]),
        ("4x2", &[2, 2, 2, 2], &[4, 4, 2, 2, 1, 1, 8, 8]),
        ("1x8", &[8], &[1, 1, 2, 4, 2, 2, 4, 8]),
        ("mixed", &[2, 4, 2], &[8, 8, 1, 1, 2, 4, 16, 16]),
    ];
    for ingest_threads in [1usize, 0] {
        let mut cfg = quickstart();
        cfg.ingest_threads = ingest_threads;
        let core = FoldCore::from_config(&cfg, N_PARAMS);
        for (tag, sizes, weights) in splits {
            let n_clients: usize = sizes.iter().sum();
            assert_eq!(n_clients, weights.len(), "{tag}: bad fixture");

            // flat baseline: every client folds straight into one root,
            // in site-major order
            let mut flat = core.begin();
            for c in 0..n_clients {
                core.fold_encoded(
                    &mut flat,
                    c as u32,
                    Encoded::Dense(dyadic_delta(c)),
                    &stats(weights[c]),
                    1.0,
                )
                .unwrap();
            }
            let (flat_delta, flat_w) = flat.finalize_delta().unwrap();

            // two-tier: per-site folds, each re-encoded exactly the way
            // the site aggregator reports upstream (f64 mean → f32 →
            // wire encoding → root fold weighted by the summed mass)
            let mut root = core.begin();
            let mut next = 0usize;
            for (site, &size) in sizes.iter().enumerate() {
                let members = next..next + size;
                next += size;
                let mut site_agg = core.begin();
                for c in members {
                    core.fold_encoded(
                        &mut site_agg,
                        c as u32,
                        Encoded::Dense(dyadic_delta(c)),
                        &stats(weights[c]),
                        1.0,
                    )
                    .unwrap();
                }
                let (site_delta, site_w) = site_agg.finalize_delta().unwrap();
                let mean_f32: Vec<f32> = site_delta.delta.iter().map(|&d| d as f32).collect();
                let enc = compress(&mean_f32, &CompressionConfig::NONE, site as u64);
                let report = UpdateStats {
                    n_samples: (site_w.round() as u64).max(1),
                    train_loss: site_delta.mean_train_loss as f32,
                    steps: size as u32,
                    compute_ms: 1.0,
                    update_var: 0.0,
                };
                core.fold_encoded(&mut root, site as u32, enc, &report, 1.0)
                    .unwrap();
            }
            let (tree_delta, tree_w) = root.finalize_delta().unwrap();

            assert_eq!(
                flat_w.to_bits(),
                tree_w.to_bits(),
                "{tag}/threads={ingest_threads}: weight mass diverged"
            );
            assert_eq!(
                bits(&flat_delta.delta),
                bits(&tree_delta.delta),
                "{tag}/threads={ingest_threads}: two-tier delta is not bit-identical to flat"
            );
            assert_eq!(
                flat_delta.mean_train_loss.to_bits(),
                tree_delta.mean_train_loss.to_bits(),
                "{tag}/threads={ingest_threads}: mean loss diverged"
            );
        }
    }
}

/// A two-tier virtual-time scenario on the quickstart fleet: 8 clients
/// under 2 site aggregators, stragglers injected, deadline armed.
fn tree_sim_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = quickstart();
    cfg.name = name.into();
    cfg.mock_runtime = true;
    cfg.train.rounds = 6;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.data.partition = Partition::Iid;
    cfg.selection.clients_per_round = 8;
    cfg.straggler.deadline_ms = Some(2_000);
    cfg.faults.straggler_prob = 0.3;
    cfg.faults.straggler_factor = 3.0;
    cfg.hierarchy.grouping = GroupingPolicy::Site { sites: 2 };
    cfg
}

/// Run-twice determinism for the two-tier **sync** sim, plus the
/// cross-facility byte claim against the equivalent flat run.
#[test]
fn two_tier_sync_sim_replays_bit_identically_and_cuts_wire_bytes() {
    let cfg = tree_sim_cfg("hierarchy_sync_det");
    let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    assert_eq!(a.details, b.details, "reporter sets diverged");
    assert_eq!(a.model_hash, b.model_hash, "model hash diverged");
    assert!(a.model_hash.is_some());
    assert_eq!(
        a.total_time_s.to_bits(),
        b.total_time_s.to_bits(),
        "virtual durations diverged"
    );
    // a different seed produces a different trajectory
    let mut reseeded = cfg.clone();
    reseeded.seed += 1;
    let c = run_sim(&reseeded, &SimTiming::default(), true).unwrap();
    assert_ne!(a.details, c.details, "seed had no effect");

    // the tree crosses facilities with O(sites) traffic, flat with
    // O(clients): per-round up/down bytes must both shrink
    let mut flat_cfg = cfg.clone();
    flat_cfg.name = "hierarchy_sync_flat".into();
    flat_cfg.hierarchy.grouping = GroupingPolicy::Flat;
    let flat = run_sim(&flat_cfg, &SimTiming::default(), true).unwrap();
    let up = |r: &fedhpc::experiments::SimReport| -> u64 {
        r.report.rounds.iter().map(|m| m.bytes_up).sum()
    };
    let down = |r: &fedhpc::experiments::SimReport| -> u64 {
        r.report.rounds.iter().map(|m| m.bytes_down).sum()
    };
    assert!(
        up(&a) < up(&flat),
        "tree up {} should undercut flat up {}",
        up(&a),
        up(&flat)
    );
    assert!(
        down(&a) < down(&flat),
        "tree down {} should undercut flat down {}",
        down(&a),
        down(&flat)
    );
}

/// Run-twice determinism for the two-tier **async_fedbuff** sim: site
/// reports arrive as staleness-tagged updates and every commit closes
/// on `buffer_k` site reports.
#[test]
fn two_tier_async_sim_replays_bit_identically() {
    let mut cfg = tree_sim_cfg("hierarchy_async_det");
    cfg.round_mode = RoundMode::BufferedAsync {
        buffer_k: 2,
        max_staleness: 50,
        staleness: StalenessFn::Polynomial { alpha: 0.5 },
    };
    let a = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    let b = run_sim(&cfg, &SimTiming::default(), true).unwrap();
    assert_eq!(a.details, b.details, "reporter sets diverged");
    assert_eq!(a.model_hash, b.model_hash, "model hash diverged");
    assert_eq!(
        a.total_time_s.to_bits(),
        b.total_time_s.to_bits(),
        "virtual durations diverged"
    );
    assert_eq!(a.report.rounds.len(), 6);
    for r in &a.report.rounds {
        assert!(
            r.reported >= 1 && r.reported <= 2,
            "commit {} closed on {} site reports",
            r.round,
            r.reported
        );
    }
}

fn member_profile() -> ClientProfile {
    ClientProfile {
        speed_factor: 1.0,
        mem_gb: 16.0,
        link_bw: 1e9,
        n_samples: 64,
        bench_step_ms: 10.0,
    }
}

/// A hand-driven site member: registers, answers every `RoundStart`
/// with a fixed dense update, exits on `Shutdown`. Bounded so a broken
/// aggregator can never hang the test harness.
fn run_member<T: ClientTransport>(c: T, n_params: usize) {
    let _ = c.send(&Msg::Register {
        client: c.id(),
        profile: member_profile(),
    });
    for _ in 0..300 {
        let msg = match c.recv_timeout(Duration::from_millis(100)) {
            Ok(Some(m)) => m,
            Ok(None) => continue,
            Err(_) => return,
        };
        match msg {
            Msg::RoundStart {
                round,
                model_version,
                ..
            } => {
                let _ = c.send(&Msg::Update {
                    round,
                    client: c.id(),
                    base_version: model_version,
                    delta: Encoded::Dense(vec![0.01; n_params]),
                    stats: stats(64),
                });
            }
            Msg::Shutdown => return,
            _ => {}
        }
    }
}

/// Graceful degradation: one live site aggregator (two members) plus
/// one site whose aggregator registered and then crashed (goes silent
/// forever). The root must still commit every round from the surviving
/// site — a dead site is just one missing reporter.
#[test]
fn root_survives_a_crashed_site_aggregator() {
    let mut cfg = quickstart();
    cfg.mock_runtime = true;
    cfg.train.rounds = 2;
    cfg.train.local_epochs = 1;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.selection.clients_per_round = 2;
    cfg.straggler.deadline_ms = Some(1_500);

    // centralized eval + initial model, exactly as the launcher builds them
    let dataset = FederatedDataset::build(&cfg.data, 8, cfg.seed).unwrap();
    let eval_runtime: Box<dyn ModelRuntime> =
        Box::new(MockRuntime::new(dataset.eval.x_len, dataset.n_classes));
    let initial = eval_runtime.init(cfg.seed as u32).unwrap();
    let n_params = initial.len();
    let eval = EvalHarness {
        runtime: eval_runtime,
        shard: dataset.eval.clone(),
    };

    let traffic = Arc::new(TrafficLog::new());
    let root_hub = InprocHub::new(traffic.clone());
    let live_up = root_hub.add_client(0, LinkShaper::unshaped());
    let dead_up = root_hub.add_client(4, LinkShaper::unshaped());

    // site 0: a real aggregator over two hand-driven members
    let site_hub = InprocHub::new(Arc::new(TrafficLog::new()));
    let mut handles = Vec::new();
    for m in [1u32, 2] {
        let endpoint = site_hub.add_client(m, LinkShaper::unshaped());
        handles.push(std::thread::spawn(move || run_member(endpoint, n_params)));
    }
    let mut agg = Aggregator::new(cfg.clone(), 0, n_params, site_hub.server(), live_up);
    handles.push(std::thread::spawn(move || {
        agg.run(2, Duration::from_secs(10)).unwrap();
    }));

    // site 1's aggregator "crashes" right after joining: it registers
    // and never speaks again (the transport stays connected)
    dead_up
        .send(&Msg::Register {
            client: 4,
            profile: member_profile(),
        })
        .unwrap();

    let mut orch = Orchestrator::builder(cfg)
        .transport(root_hub.server())
        .traffic(traffic)
        .initial_params(initial)
        .eval(eval)
        .build()
        .unwrap();
    let report = orch
        .run(Some((2, Duration::from_secs(10))), &mut NoHooks)
        .unwrap();

    assert_eq!(report.rounds.len(), 2);
    for r in &report.rounds {
        assert_eq!(r.selected, 2, "root must still select the dead site");
        assert_eq!(
            r.reported, 1,
            "round {} should commit from the surviving site alone",
            r.round
        );
    }
    assert!(report.final_accuracy().is_some());
    drop(dead_up);
    for h in handles {
        h.join().unwrap();
    }
}
