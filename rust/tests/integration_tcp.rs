//! Multi-process-shaped integration: a full federation over the framed
//! TCP transport ("gRPC" path) — real sockets, reader threads, large
//! model frames — with the orchestrator and workers in separate
//! threads, as `fedhpc serve` / `fedhpc worker` would run them in
//! separate processes.

use fedhpc::client::{Worker, WorkerOptions};
use fedhpc::cluster::Cluster;
use fedhpc::config::presets::quickstart;
use fedhpc::data::FederatedDataset;
use fedhpc::faults::FaultInjector;
use fedhpc::network::tcp::{TcpClient, TcpServer};
use fedhpc::network::{LinkShaper, Msg, TrafficLog};
use fedhpc::orchestrator::{EvalHarness, NoHooks, Orchestrator};
use fedhpc::runtime::{MockRuntime, ModelRuntime};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn tcp_federation_end_to_end() {
    let mut cfg = quickstart();
    cfg.name = "it_tcp".into();
    cfg.mock_runtime = true;
    cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 4)];
    cfg.selection.clients_per_round = 3;
    cfg.train.rounds = 3;
    cfg.train.local_epochs = 1;
    cfg.train.lr = 0.2;
    cfg.data.samples_per_client = 64;
    cfg.data.eval_samples = 128;
    cfg.data.partition = fedhpc::config::Partition::Iid;
    cfg.straggler.deadline_ms = Some(30_000);

    let n = cfg.cluster.total_nodes();
    let cluster = Cluster::build(&cfg.cluster, cfg.seed).unwrap();
    let dataset = FederatedDataset::build(&cfg.data, n, cfg.seed).unwrap();

    let traffic = Arc::new(TrafficLog::new());
    let server = TcpServer::bind("127.0.0.1:0", traffic.clone()).unwrap();
    let addr = server.local_addr.to_string();

    // workers, each over its own TCP connection
    let mut handles = Vec::new();
    for (node, shard) in cluster.nodes.iter().zip(&dataset.clients) {
        let rt = MockRuntime::new(shard.x_len, dataset.n_classes);
        let profile =
            fedhpc::client::profile_runtime(&rt, node, shard, 0).unwrap();
        let transport = TcpClient::connect(
            &addr,
            &Msg::Register {
                client: node.id,
                profile,
            },
            LinkShaper::unshaped(),
            Arc::new(TrafficLog::new()),
        )
        .unwrap();
        let worker = Worker::new(
            transport,
            Box::new(rt),
            node.clone(),
            shard.clone(),
            FaultInjector::disabled(),
            WorkerOptions {
                emulate_speed: false,
                seed: cfg.seed ^ node.id as u64,
                ..Default::default()
            },
        );
        handles.push(std::thread::spawn(move || worker.run()));
    }

    // orchestrator over the same socket server
    let eval_rt = MockRuntime::new(dataset.eval.x_len, dataset.n_classes);
    let initial = eval_rt.init(cfg.seed as u32).unwrap();
    let eval = EvalHarness {
        runtime: Box::new(eval_rt),
        shard: dataset.eval.clone(),
    };
    let mut orch = Orchestrator::builder(cfg.clone())
        .transport(server)
        .traffic(traffic)
        .initial_params(initial)
        .eval(eval)
        .build()
        .unwrap();
    let report = orch
        .run(Some((n, Duration::from_secs(30))), &mut NoHooks)
        .unwrap();

    assert_eq!(report.rounds.len(), 3);
    for r in &report.rounds {
        assert_eq!(r.reported, 3, "round {} lost updates over TCP", r.round);
    }
    assert!(report.final_accuracy().unwrap() > 0.3);
    for h in handles {
        let rounds = h.join().unwrap().unwrap();
        assert!(rounds <= 3);
    }
}
