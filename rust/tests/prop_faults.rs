//! Property tests for `faults::FaultInjector` (ISSUE 4 satellite):
//! the deterministic fault oracle the straggler-resilience story rests
//! on. Pins (1) replay determinism per (seed, round, client), (2)
//! calibration — empirical action frequencies over 10k draws match the
//! configured rates, (3) `reports_update()` consistency with the
//! severity ordering the worker path applies (the worker-side halves
//! of (3) — dropout/preempt suppress the Update, straggle still sends
//! one — are pinned in `client::worker`'s unit tests).

use fedhpc::config::FaultConfig;
use fedhpc::faults::{FaultAction, FaultInjector};
use fedhpc::testkit::{check, Gen};

fn any_cfg(g: &mut Gen) -> FaultConfig {
    FaultConfig {
        dropout_prob: g.f64_in(0.0, 0.6),
        preemption_prob: g.f64_in(0.0, 0.6),
        straggler_prob: g.f64_in(0.0, 0.6),
        straggler_factor: g.f64_in(1.0, 8.0),
    }
}

/// Same (seed, round, client, is_spot) ⇒ identical action — across
/// repeated calls *and* across freshly constructed injectors (nothing
/// hides mutable state).
#[test]
fn prop_fault_action_is_deterministic() {
    check("fault determinism", 200, |g| {
        let cfg = any_cfg(g);
        let seed = g.rng.next_u64();
        let inj_a = FaultInjector::new(cfg, seed);
        let inj_b = FaultInjector::new(cfg, seed);
        for _ in 0..20 {
            let round = g.usize_in(0, 10_000) as u32;
            let client = g.usize_in(0, 10_000) as u32;
            let spot = g.bool();
            let first = inj_a.action(round, client, spot);
            assert_eq!(first, inj_a.action(round, client, spot));
            assert_eq!(first, inj_b.action(round, client, spot));
        }
        // and a different seed decorrelates (not a fixed function of
        // (round, client) alone) — checked only when faults can fire
        if cfg.dropout_prob > 0.1 {
            let inj_c = FaultInjector::new(cfg, seed ^ 0xDEAD_BEEF);
            let diverged = (0..200).any(|i| {
                inj_a.action(i, i, true) != inj_c.action(i, i, true)
            });
            assert!(diverged, "seed does not influence the oracle");
        }
    });
}

/// Empirical action frequencies over 10k (round, client) draws match
/// the configured rates. The oracle checks in severity order —
/// dropout, then preemption (spot only), then straggle — so the
/// expected marginals are the chained conditionals.
#[test]
fn prop_fault_frequencies_match_configured_rates() {
    check("fault frequencies", 12, |g| {
        let cfg = FaultConfig {
            dropout_prob: g.f64_in(0.05, 0.4),
            preemption_prob: g.f64_in(0.05, 0.4),
            straggler_prob: g.f64_in(0.05, 0.4),
            straggler_factor: 4.0,
        };
        let seed = g.rng.next_u64();
        let spot = g.bool();
        let inj = FaultInjector::new(cfg, seed);
        let n = 10_000u32;
        let (mut drops, mut preempts, mut straggles) = (0u32, 0u32, 0u32);
        for i in 0..n {
            match inj.action(i / 100, i % 100, spot) {
                FaultAction::Dropout => drops += 1,
                FaultAction::Preempt { progress } => {
                    assert!((0.0..=1.0).contains(&progress));
                    preempts += 1;
                }
                FaultAction::Straggle { factor } => {
                    assert_eq!(factor, 4.0);
                    straggles += 1;
                }
                FaultAction::None => {}
            }
        }
        // 3σ tolerance for a Bernoulli(p) sample of n=10k is
        // ~3·√(0.25/10k) < 0.015; allow 0.02
        let tol = 0.02;
        let p_drop = cfg.dropout_prob;
        let p_pre = if spot {
            (1.0 - p_drop) * cfg.preemption_prob
        } else {
            0.0
        };
        let p_straggle = (1.0 - p_drop)
            * (1.0 - if spot { cfg.preemption_prob } else { 0.0 })
            * cfg.straggler_prob;
        let rate = |c: u32| c as f64 / n as f64;
        assert!(
            (rate(drops) - p_drop).abs() < tol,
            "dropout rate {} vs {p_drop}",
            rate(drops)
        );
        assert!(
            (rate(preempts) - p_pre).abs() < tol,
            "preempt rate {} vs {p_pre} (spot={spot})",
            rate(preempts)
        );
        assert!(
            (rate(straggles) - p_straggle).abs() < tol,
            "straggle rate {} vs {p_straggle}",
            rate(straggles)
        );
        if !spot {
            assert_eq!(preempts, 0, "preemption must only hit spot nodes");
        }
    });
}

/// `reports_update()` is exactly "an Update message reaches the
/// server": true for None/Straggle, false for Dropout/Preempt — for
/// every action the oracle can produce.
#[test]
fn prop_reports_update_matches_action_kind() {
    check("reports_update", 100, |g| {
        let cfg = any_cfg(g);
        let inj = FaultInjector::new(cfg, g.rng.next_u64());
        for _ in 0..100 {
            let action = inj.action(
                g.usize_in(0, 1000) as u32,
                g.usize_in(0, 1000) as u32,
                g.bool(),
            );
            let expect = matches!(action, FaultAction::None | FaultAction::Straggle { .. });
            assert_eq!(action.reports_update(), expect, "{action:?}");
            if let FaultAction::Straggle { factor } = action {
                assert!(factor >= 1.0, "straggle must never speed a client up");
            }
        }
    });
}
